//! Control-theoretic PID auto-scaler on the delay error.
//!
//! The survey's control-theoretic family (PAPERS.md): treat the cluster
//! as a plant whose output is the time-in-system, and drive it to a
//! setpoint with a proportional–integral–derivative loop. The measured
//! signal is the *implied drain time* — outstanding service demand
//! `in_system · E[S]` spread over the effective capacity — against a
//! setpoint of half the SLA, normalized by the SLA so the gains are
//! unitless and portable across configurations.
//!
//! Two classical refinements, both pinned by property tests:
//!
//! * **Anti-windup.** The integrator is clamped so its contribution
//!   alone can never exceed the actuation clamp [`PidScaler::MAX_STEP`];
//!   together with the output clamp, no error sequence — step, ramp or
//!   adversarial — can make one decision move the fleet by more than
//!   `MAX_STEP` CPUs.
//! * **Gain scheduling.** The proportional/derivative gains scale with
//!   the error regime: ×2 once the implied delay blows past the SLA,
//!   ×1.5 in the warning band, ×1 near the setpoint; inside a ±5% dead
//!   band the controller holds entirely.
//!
//! State (integral, previous error) evolves only from the observation
//! sequence, which is identical across the serial engine, the lockstep
//! batch kernel and the threaded runner — so decisions stay
//! bit-identical everywhere, and repeated calls at the same timestamp
//! (dt = 0) are idempotent.

use super::{AutoScaler, Decision, Observation};
use crate::delay::DelayModel;
use crate::workload::TweetClass;

/// PID controller on the normalized delay error.
#[derive(Debug, Clone)]
pub struct PidScaler {
    /// Pessimistic per-tweet cycle estimate (same role as in `LoadScaler`).
    cycles_per_tweet: f64,
    /// Proportional gain, > 0.
    pub kp: f64,
    /// Integral gain, ≥ 0 (0 disables the integrator).
    pub ki: f64,
    /// Derivative gain, ≥ 0.
    pub kd: f64,
    /// Accumulated error·dt, clamped for anti-windup.
    integral: f64,
    /// Previous (time, error) sample for the derivative term.
    prev: Option<(f64, f64)>,
}

impl PidScaler {
    /// Hard actuation clamp: one decision never moves the fleet by more
    /// than this many CPUs, regardless of the error history.
    pub const MAX_STEP: f64 = 8.0;

    /// Dead band on the normalized error: within ±5% of the setpoint the
    /// controller holds.
    pub const DEAD_BAND: f64 = 0.05;

    /// PID on the delay error with the load family's a-priori knowledge
    /// (`model`, `quantile`, `class_mix`) and gains `kp` (> 0),
    /// `ki`/`kd` (≥ 0).
    pub fn new(
        model: DelayModel,
        quantile: f64,
        class_mix: [f64; 3],
        kp: f64,
        ki: f64,
        kd: f64,
    ) -> Self {
        assert!(kp > 0.0 && kp.is_finite(), "kp out of (0,inf): {kp}");
        assert!(ki >= 0.0 && ki.is_finite(), "ki out of [0,inf): {ki}");
        assert!(kd >= 0.0 && kd.is_finite(), "kd out of [0,inf): {kd}");
        let cycles_per_tweet = TweetClass::ALL
            .iter()
            .map(|&c| class_mix[c as usize] * model.quantile_cycles(c, quantile))
            .sum();
        Self { cycles_per_tweet, kp, ki, kd, integral: 0.0, prev: None }
    }

    /// Normalized delay error for an observation: implied drain time vs
    /// a setpoint of half the SLA, in SLA units.
    pub fn error(&self, obs: &Observation<'_>) -> f64 {
        let s = self.cycles_per_tweet / obs.cpu_hz;
        let effective = f64::from((obs.cpus + obs.pending_cpus).max(1));
        let drain_secs = obs.in_system as f64 * s / effective;
        (drain_secs - 0.5 * obs.sla_secs) / obs.sla_secs
    }

    /// The integrator's current contribution to the output (`ki · ∫e`);
    /// anti-windup keeps `|integral_term| ≤ MAX_STEP` at all times.
    pub fn integral_term(&self) -> f64 {
        self.ki * self.integral
    }

    /// Gain schedule: amplify P/D as the error leaves the comfort zone.
    fn schedule(e_abs: f64) -> f64 {
        if e_abs >= 1.0 {
            2.0
        } else if e_abs >= 0.5 {
            1.5
        } else {
            1.0
        }
    }
}

impl AutoScaler for PidScaler {
    fn decide(&mut self, obs: &Observation<'_>) -> Decision {
        let e = self.error(obs);
        let dt = self.prev.map_or(0.0, |(t, _)| obs.now - t);
        let de = match self.prev {
            Some((_, pe)) if dt > 1e-9 => (e - pe) / dt,
            _ => 0.0,
        };
        if dt > 1e-9 && self.ki > 0.0 {
            // Clamping anti-windup: the integrated error can never push
            // the output further than the actuation clamp on its own.
            let cap = Self::MAX_STEP / self.ki;
            self.integral = (self.integral + e * dt).clamp(-cap, cap);
        }
        if dt > 1e-9 || self.prev.is_none() {
            self.prev = Some((obs.now, e));
        }
        if e.abs() < Self::DEAD_BAND {
            return Decision::Hold;
        }
        let g = Self::schedule(e.abs());
        let u = (g * (self.kp * e + self.kd * de) + self.integral_term())
            .clamp(-Self::MAX_STEP, Self::MAX_STEP);
        let n = u.round();
        if n >= 1.0 {
            Decision::ScaleOut(n as u32)
        } else if n <= -1.0 && obs.cpus > 1 {
            Decision::ScaleIn((-n as u32).min(obs.cpus - 1))
        } else {
            Decision::Hold
        }
    }

    fn name(&self) -> String {
        format!(
            "pid-{}-{}-{}",
            super::fmt_param(self.kp),
            super::fmt_param(self.ki),
            super::fmt_param(self.kd)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::history::SentimentWindows;

    fn scaler(kp: f64, ki: f64, kd: f64) -> PidScaler {
        PidScaler::new(DelayModel::default(), 0.99999, [0.3, 0.3, 0.4], kp, ki, kd)
    }

    fn obs(now: f64, in_system: usize, cpus: u32, w: &SentimentWindows) -> Observation<'_> {
        Observation {
            now,
            cpus,
            pending_cpus: 0,
            in_system,
            cpu_usage: 0.8,
            sentiment: w,
            nodes: &[],
            cpu_hz: 2.0e9,
            sla_secs: 300.0,
        }
    }

    /// In-system count whose implied drain time sits exactly at the
    /// setpoint for one CPU (error 0).
    fn setpoint_load(s: &PidScaler) -> usize {
        let w = SentimentWindows::new();
        let mut lo = 0usize;
        let mut hi = 10_000_000;
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            if s.error(&obs(0.0, mid, 1, &w)) < 0.0 {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }

    #[test]
    fn at_setpoint_holds() {
        let w = SentimentWindows::new();
        let mut s = scaler(2.0, 0.01, 0.0);
        let load = setpoint_load(&s);
        for t in 0..10 {
            assert_eq!(s.decide(&obs(t as f64 * 60.0, load, 1, &w)), Decision::Hold);
        }
    }

    #[test]
    fn sustained_overload_scales_out_up_to_the_clamp() {
        let w = SentimentWindows::new();
        let mut s = scaler(4.0, 0.05, 0.0);
        let mut saw_clamp = false;
        for t in 0..50 {
            match s.decide(&obs(t as f64 * 60.0, 50_000_000, 1, &w)) {
                Decision::ScaleOut(n) => {
                    assert!(f64::from(n) <= PidScaler::MAX_STEP, "step {n} over clamp");
                    saw_clamp |= f64::from(n) == PidScaler::MAX_STEP;
                }
                d => panic!("expected scale-out under overload, got {d:?}"),
            }
        }
        assert!(saw_clamp, "integral should drive the output to the clamp");
    }

    #[test]
    fn idle_fleet_scales_in_and_survives_at_one() {
        let w = SentimentWindows::new();
        let mut s = scaler(4.0, 0.0, 0.0);
        s.decide(&obs(0.0, 0, 8, &w));
        match s.decide(&obs(60.0, 0, 8, &w)) {
            Decision::ScaleIn(n) => assert!(n >= 1 && n <= 7),
            d => panic!("expected scale-in when idle, got {d:?}"),
        }
        assert_eq!(s.decide(&obs(120.0, 0, 1, &w)), Decision::Hold);
    }

    #[test]
    fn integral_term_is_windup_bounded() {
        let w = SentimentWindows::new();
        let mut s = scaler(1.0, 0.5, 0.0);
        for t in 0..10_000 {
            s.decide(&obs(t as f64 * 60.0, 100_000_000, 1, &w));
            assert!(s.integral_term().abs() <= PidScaler::MAX_STEP + 1e-12);
        }
    }

    #[test]
    fn repeated_observation_at_same_time_is_idempotent() {
        let w = SentimentWindows::new();
        let mut s = scaler(2.0, 0.1, 0.5);
        let o = obs(60.0, 1_000_000, 2, &w);
        s.decide(&obs(0.0, 900_000, 2, &w));
        let first = s.decide(&o);
        for _ in 0..5 {
            assert_eq!(s.decide(&o), first, "dt = 0 must not mutate state");
        }
    }

    #[test]
    fn derivative_reacts_to_a_rising_ramp() {
        let w = SentimentWindows::new();
        // Pure-D controller: flat load decides Hold, ramping load acts.
        let mut flat = scaler(0.001, 0.0, 2000.0);
        let mut ramp = scaler(0.001, 0.0, 2000.0);
        let base = 10_000_000usize;
        let mut ramp_acted = false;
        for t in 1..8 {
            assert_eq!(
                flat.decide(&obs(t as f64 * 60.0, base, 4, &w)),
                Decision::Hold,
                "flat load, negligible P"
            );
            let rising = base + t as usize * 4_000_000;
            if let Decision::ScaleOut(_) = ramp.decide(&obs(t as f64 * 60.0, rising, 4, &w)) {
                ramp_acted = true;
            }
        }
        assert!(ramp_acted, "derivative term must anticipate the ramp");
    }

    #[test]
    fn name_encodes_all_three_gains() {
        assert_eq!(scaler(2.0, 0.5, 0.25).name(), "pid-2-0.5-0.25");
        assert_eq!(scaler(1.5, 0.0, 0.0).name(), "pid-1.5-0-0");
    }

    #[test]
    #[should_panic(expected = "kp out of")]
    fn non_positive_kp_rejected() {
        scaler(0.0, 0.1, 0.1);
    }

    #[test]
    #[should_panic(expected = "ki out of")]
    fn negative_ki_rejected() {
        scaler(1.0, -0.1, 0.1);
    }
}
