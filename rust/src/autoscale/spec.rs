//! Declarative scaler specifications: the registry that turns a name +
//! parameters into any [`AutoScaler`] the crate knows how to build.
//!
//! Every experiment scenario used to carry its own `Fn() -> Box<dyn
//! AutoScaler>` factory closure; a [`ScalerSpec`] is the data those
//! closures were hiding. Specs are plain values (`Send + Sync`), so the
//! parallel scenario runner can rebuild a fresh scaler per replication on
//! any thread, and they round-trip through their string form so the CLI
//! can accept arbitrary scaler grids.
//!
//! String grammar (each form equals the built scaler's `name()`):
//!
//! ```text
//! threshold-60%                 CPU-usage threshold rule (upper bound %)
//! load-q99.999%                 a-priori load algorithm at a quantile
//! appdata+4                     sentiment-peak detector, +4 CPUs per peak
//! appdata+4@w60                 ... with a non-default 60 s window
//! predictive-h120s              linear-trend forecast, 120 s horizon
//! vertical-ladder               instance-type ladder (vertical scaling)
//! depas-0.7-0.1-0.5             decentralized probabilistic fleet
//!                               (target T, band half-width Δ, damping γ)
//! queueing-0.7-0.5              Little's-law sizing (utilization ρ,
//!                               wait target as a fraction of the SLA)
//! pid-2-0.5-0.25                PID on the delay error (kp, ki, kd)
//! hybrid-80-120                 reactive threshold % + predictive
//!                               horizon s, switched on forecast error
//! load-q99.999%+appdata+4       composite: base "+" peak detector
//! ```
//!
//! Every form round-trips: parsing a spec string and re-rendering it
//! yields the same string, and the built scaler's `name()` matches too.
//!
//! ```
//! use sla_autoscale::autoscale::ScalerSpec;
//! for form in [
//!     "threshold-60%",
//!     "load-q99.999%",
//!     "appdata+4",
//!     "appdata+4@w60",
//!     "predictive-h120s",
//!     "vertical-ladder",
//!     "depas-0.7-0.1-0.5",
//!     "queueing-0.7-0.5",
//!     "pid-2-0.5-0.25",
//!     "hybrid-80-120",
//!     "load-q99.999%+appdata+4",
//!     "depas-0.7-0.1-0.5+appdata+2",
//!     "queueing-0.7-0.5+appdata+2",
//! ] {
//!     assert_eq!(ScalerSpec::parse(form).unwrap().to_string(), form);
//! }
//! ```

use super::{
    AppdataScaler, AutoScaler, Composite as CompositeScaler, DepasScaler, HybridScaler,
    LoadScaler, PidScaler, PredictiveScaler, QueueingScaler, ThresholdScaler, VerticalScaler,
};
use crate::delay::DelayModel;
use anyhow::{bail, Result};
use std::fmt;

/// Quantile used by registry-built `predictive` / `vertical` scalers
/// (the paper's headline setting; not encoded in their names).
pub const REGISTRY_QUANTILE: f64 = 0.99999;

/// A buildable description of one auto-scaling algorithm configuration.
#[derive(Debug, Clone, PartialEq)]
pub enum ScalerSpec {
    /// CPU-usage threshold rule; `upper_pct` in (0, 100].
    Threshold { upper_pct: f64 },
    /// A-priori *load* algorithm; `quantile` in (0, 1).
    Load { quantile: f64 },
    /// Application-data peak detector (never scales in on its own).
    Appdata { extra: u32, window_secs: f64 },
    /// Linear-trend forecaster over in-system counts.
    Predictive { horizon_secs: f64 },
    /// Instance-type ladder (vertical scaling on the horizontal API).
    Vertical,
    /// Decentralized probabilistic fleet (DEPAS): every node votes to
    /// spawn/terminate on its own local view of the load. `target` in
    /// (0, 1), `band` in (0, min(target, 1 − target)), `gamma` in (0, 1].
    Depas { target: f64, band: f64, gamma: f64 },
    /// Little's-law target sizing; `rho` in (0, 1), `w_frac` in (0, 1].
    Queueing { rho: f64, w_frac: f64 },
    /// PID on the delay error; `kp` > 0, `ki`/`kd` ≥ 0.
    Pid { kp: f64, ki: f64, kd: f64 },
    /// Reactive threshold (`upper_pct` in (0, 100]) + predictive
    /// forecaster (`horizon_secs` > 0), switched on forecast error.
    Hybrid { upper_pct: f64, horizon_secs: f64 },
    /// `base` handles ordinary traffic, `peaks` pre-provisions bursts.
    Composite { base: Box<ScalerSpec>, peaks: Box<ScalerSpec> },
}

impl ScalerSpec {
    /// Threshold rule from an upper bound in percent (e.g. `60.0`).
    pub fn threshold(upper_pct: f64) -> Self {
        Self::Threshold { upper_pct }
    }

    /// Load algorithm at a quantile in (0, 1) (e.g. `0.99999`).
    pub fn load(quantile: f64) -> Self {
        Self::Load { quantile }
    }

    /// Appdata detector with the paper's tuned 120 s window.
    pub fn appdata(extra: u32) -> Self {
        Self::Appdata { extra, window_secs: AppdataScaler::DEFAULT_WINDOW_SECS }
    }

    /// Appdata detector with an explicit comparison window.
    pub fn appdata_windowed(extra: u32, window_secs: f64) -> Self {
        Self::Appdata { extra, window_secs }
    }

    /// Predictive scaler with the given forecast horizon (seconds).
    pub fn predictive(horizon_secs: f64) -> Self {
        Self::Predictive { horizon_secs }
    }

    /// DEPAS fleet steering toward `target` utilization with dead-band
    /// half-width `band` and damping `gamma` (see [`DepasScaler`] for
    /// the decision rule and parameter constraints).
    pub fn depas(target: f64, band: f64, gamma: f64) -> Self {
        Self::Depas { target, band, gamma }
    }

    /// Little's-law sizing toward utilization `rho` in (0, 1) with a
    /// wait target of `w_frac` of the SLA (see [`QueueingScaler`]).
    pub fn queueing(rho: f64, w_frac: f64) -> Self {
        Self::Queueing { rho, w_frac }
    }

    /// PID on the delay error with gains `kp`/`ki`/`kd` (see
    /// [`PidScaler`] for the loop and its anti-windup clamp).
    pub fn pid(kp: f64, ki: f64, kd: f64) -> Self {
        Self::Pid { kp, ki, kd }
    }

    /// Hybrid of `threshold-<upper_pct>%` and
    /// `predictive-h<horizon_secs>s`, switched on observed forecast
    /// error (see [`HybridScaler`]).
    pub fn hybrid(upper_pct: f64, horizon_secs: f64) -> Self {
        Self::Hybrid { upper_pct, horizon_secs }
    }

    /// Composite of two specs (`base` + `peaks`).
    pub fn composite(base: ScalerSpec, peaks: ScalerSpec) -> Self {
        Self::Composite { base: Box::new(base), peaks: Box::new(peaks) }
    }

    /// The paper's §V-B configuration: load at `quantile` plus the appdata
    /// peak detector pre-provisioning `extra` CPUs.
    pub fn load_plus_appdata(quantile: f64, extra: u32) -> Self {
        Self::composite(Self::load(quantile), Self::appdata(extra))
    }

    /// The paper's threshold sweep: 60..99% upper bounds (Fig 7).
    pub fn threshold_sweep() -> Vec<Self> {
        [60.0, 70.0, 80.0, 90.0, 99.0].into_iter().map(Self::threshold).collect()
    }

    /// The paper's load-quantile sweep: q = 0.9 .. 0.99999 (Fig 7).
    pub fn load_sweep() -> Vec<Self> {
        [0.90, 0.99, 0.999, 0.9999, 0.99999].into_iter().map(Self::load).collect()
    }

    /// The paper's appdata sweep: load(`quantile`) + 1..=10 extra CPUs (Fig 8).
    pub fn appdata_sweep(quantile: f64) -> Vec<Self> {
        (1..=10).map(|extra| Self::load_plus_appdata(quantile, extra)).collect()
    }

    /// Construct the scaler this spec describes. `model` and `mix` are the
    /// a-priori knowledge (per-class cycle distributions, class mix) the
    /// load-family algorithms assume.
    ///
    /// The built scaler's `name()` always equals the spec's string form:
    ///
    /// ```
    /// use sla_autoscale::autoscale::{AutoScaler, ScalerSpec};
    /// use sla_autoscale::delay::DelayModel;
    /// let spec = ScalerSpec::parse("load-q99.999%+appdata+4").unwrap();
    /// let scaler = spec.build(&DelayModel::default(), [0.3, 0.3, 0.4]);
    /// assert_eq!(scaler.name(), spec.to_string());
    /// ```
    pub fn build(&self, model: &DelayModel, mix: [f64; 3]) -> Box<dyn AutoScaler> {
        match self {
            Self::Threshold { upper_pct } => Box::new(ThresholdScaler::new(*upper_pct / 100.0)),
            Self::Load { quantile } => Box::new(LoadScaler::new(model.clone(), *quantile, mix)),
            Self::Appdata { extra, window_secs } => {
                let mut scaler = AppdataScaler::new(*extra);
                scaler.window_secs = *window_secs;
                Box::new(scaler)
            }
            Self::Predictive { horizon_secs } => Box::new(PredictiveScaler::new(
                model.clone(),
                REGISTRY_QUANTILE,
                mix,
                *horizon_secs,
            )),
            Self::Vertical => {
                Box::new(VerticalScaler::new(model.clone(), REGISTRY_QUANTILE, mix))
            }
            Self::Depas { target, band, gamma } => {
                Box::new(DepasScaler::new(*target, *band, *gamma))
            }
            Self::Queueing { rho, w_frac } => Box::new(QueueingScaler::new(
                model.clone(),
                REGISTRY_QUANTILE,
                mix,
                *rho,
                *w_frac,
            )),
            Self::Pid { kp, ki, kd } => Box::new(PidScaler::new(
                model.clone(),
                REGISTRY_QUANTILE,
                mix,
                *kp,
                *ki,
                *kd,
            )),
            Self::Hybrid { upper_pct, horizon_secs } => Box::new(HybridScaler::new(
                model.clone(),
                REGISTRY_QUANTILE,
                mix,
                *upper_pct / 100.0,
                *horizon_secs,
            )),
            Self::Composite { base, peaks } => Box::new(CompositeScaler::new(
                base.build(model, mix),
                peaks.build(model, mix),
            )),
        }
    }

    /// Parse the string form (see module docs for the grammar). The
    /// composite form splits at the first `+` where both sides parse.
    ///
    /// ```
    /// use sla_autoscale::autoscale::ScalerSpec;
    /// let spec = ScalerSpec::parse("depas-0.7-0.1-0.5").unwrap();
    /// assert_eq!(spec, ScalerSpec::depas(0.7, 0.1, 0.5));
    /// assert_eq!(spec.to_string(), "depas-0.7-0.1-0.5");
    /// // the band half-width must fit between the target and both ends
    /// // of the utilization range
    /// assert!(ScalerSpec::parse("depas-0.7-0.4-0.5").is_err());
    /// ```
    pub fn parse(s: &str) -> Result<Self> {
        let s = s.trim();
        if let Some(atom) = Self::parse_atom(s) {
            return Ok(atom);
        }
        for (i, c) in s.char_indices() {
            if c != '+' {
                continue;
            }
            if let Some(base) = Self::parse_atom(&s[..i]) {
                if let Ok(peaks) = Self::parse(&s[i + 1..]) {
                    return Ok(Self::composite(base, peaks));
                }
            }
        }
        bail!(
            "unknown algorithm {s:?} (expected threshold-<pct>% | load-q<pct>% | \
             appdata+<n>[@w<secs>] | predictive-h<secs>s | vertical-ladder | \
             depas-<target>-<band>-<gamma> | queueing-<rho>-<wfrac> | \
             pid-<kp>-<ki>-<kd> | hybrid-<pct>-<horizon> | <base>+<peaks>)"
        )
    }

    fn parse_atom(s: &str) -> Option<Self> {
        if let Some(rest) = s.strip_prefix("threshold-") {
            let rest = rest.strip_suffix('%').unwrap_or(rest);
            let pct: f64 = rest.parse().ok()?;
            if pct > 0.0 && pct <= 100.0 {
                return Some(Self::threshold(pct));
            }
            return None;
        }
        if let Some(rest) = s.strip_prefix("load-q") {
            let rest = rest.strip_suffix('%').unwrap_or(rest);
            let pct: f64 = rest.parse().ok()?;
            if pct > 0.0 && pct < 100.0 {
                return Some(Self::load(pct / 100.0));
            }
            return None;
        }
        if let Some(rest) = s.strip_prefix("load-") {
            // legacy CLI form: a bare quantile, e.g. load-0.99999
            let q: f64 = rest.parse().ok()?;
            if q > 0.0 && q < 1.0 {
                return Some(Self::load(q));
            }
            return None;
        }
        if let Some(rest) = s.strip_prefix("appdata+") {
            let (extra_s, window) = match rest.split_once("@w") {
                Some((e, w)) => (e, w.parse().ok()?),
                None => (rest, AppdataScaler::DEFAULT_WINDOW_SECS),
            };
            let extra: u32 = extra_s.parse().ok()?;
            if extra > 0 && window > 0.0 {
                return Some(Self::appdata_windowed(extra, window));
            }
            return None;
        }
        if let Some(rest) = s.strip_prefix("predictive-h") {
            let rest = rest.strip_suffix('s').unwrap_or(rest);
            let horizon: f64 = rest.parse().ok()?;
            if horizon > 0.0 {
                return Some(Self::predictive(horizon));
            }
            return None;
        }
        if s == "vertical-ladder" || s == "vertical" {
            return Some(Self::Vertical);
        }
        if let Some(rest) = s.strip_prefix("depas-") {
            let mut parts = rest.split('-');
            let (t, b, g) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
                (Some(t), Some(b), Some(g), None) => (t, b, g),
                _ => return None,
            };
            let target: f64 = t.parse().ok()?;
            let band: f64 = b.parse().ok()?;
            let gamma: f64 = g.parse().ok()?;
            if target > 0.0
                && target < 1.0
                && band > 0.0
                && band < target.min(1.0 - target)
                && gamma > 0.0
                && gamma <= 1.0
            {
                return Some(Self::depas(target, band, gamma));
            }
            return None;
        }
        if let Some(rest) = s.strip_prefix("queueing-") {
            let (r, w) = match rest.split_once('-') {
                Some((r, w)) if !w.contains('-') => (r, w),
                _ => return None,
            };
            let rho: f64 = r.parse().ok()?;
            let w_frac: f64 = w.parse().ok()?;
            if rho > 0.0 && rho < 1.0 && w_frac > 0.0 && w_frac <= 1.0 {
                return Some(Self::queueing(rho, w_frac));
            }
            return None;
        }
        if let Some(rest) = s.strip_prefix("pid-") {
            let mut parts = rest.split('-');
            let (p, i, d) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
                (Some(p), Some(i), Some(d), None) => (p, i, d),
                _ => return None,
            };
            let kp: f64 = p.parse().ok()?;
            let ki: f64 = i.parse().ok()?;
            let kd: f64 = d.parse().ok()?;
            if kp > 0.0 && ki >= 0.0 && kd >= 0.0 && kp.is_finite() && ki.is_finite()
                && kd.is_finite()
            {
                return Some(Self::pid(kp, ki, kd));
            }
            return None;
        }
        if let Some(rest) = s.strip_prefix("hybrid-") {
            let (p, h) = match rest.split_once('-') {
                Some((p, h)) if !h.contains('-') => (p, h),
                _ => return None,
            };
            let pct: f64 = p.parse().ok()?;
            let horizon: f64 = h.parse().ok()?;
            if pct > 0.0 && pct <= 100.0 && horizon > 0.0 {
                return Some(Self::hybrid(pct, horizon));
            }
            return None;
        }
        None
    }
}

impl fmt::Display for ScalerSpec {
    /// Must stay in lockstep with each scaler's `name()` (tested below).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Threshold { upper_pct } => {
                write!(f, "threshold-{}%", super::fmt_param(*upper_pct))
            }
            Self::Load { quantile } => {
                write!(f, "load-q{}%", super::fmt_quantile_pct(*quantile))
            }
            Self::Appdata { extra, window_secs } => {
                if (*window_secs - AppdataScaler::DEFAULT_WINDOW_SECS).abs() < 1e-9 {
                    write!(f, "appdata+{extra}")
                } else {
                    write!(f, "appdata+{extra}@w{}", super::fmt_param(*window_secs))
                }
            }
            Self::Predictive { horizon_secs } => {
                write!(f, "predictive-h{}s", super::fmt_param(*horizon_secs))
            }
            Self::Vertical => write!(f, "vertical-ladder"),
            Self::Depas { target, band, gamma } => write!(
                f,
                "depas-{}-{}-{}",
                super::fmt_param(*target),
                super::fmt_param(*band),
                super::fmt_param(*gamma)
            ),
            Self::Queueing { rho, w_frac } => write!(
                f,
                "queueing-{}-{}",
                super::fmt_param(*rho),
                super::fmt_param(*w_frac)
            ),
            Self::Pid { kp, ki, kd } => write!(
                f,
                "pid-{}-{}-{}",
                super::fmt_param(*kp),
                super::fmt_param(*ki),
                super::fmt_param(*kd)
            ),
            Self::Hybrid { upper_pct, horizon_secs } => write!(
                f,
                "hybrid-{}-{}",
                super::fmt_param(*upper_pct),
                super::fmt_param(*horizon_secs)
            ),
            Self::Composite { base, peaks } => write!(f, "{base}+{peaks}"),
        }
    }
}

impl std::str::FromStr for ScalerSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Self::parse(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mix() -> [f64; 3] {
        [0.30, 0.30, 0.40]
    }

    /// One spec per variant (plus sweeps) — the registry's full surface.
    fn registry_grid() -> Vec<ScalerSpec> {
        let mut grid = ScalerSpec::threshold_sweep();
        grid.extend(ScalerSpec::load_sweep());
        grid.push(ScalerSpec::appdata(4));
        grid.push(ScalerSpec::appdata_windowed(2, 60.0));
        grid.push(ScalerSpec::predictive(120.0));
        grid.push(ScalerSpec::Vertical);
        // non-integral parameters must survive the string form too
        grid.push(ScalerSpec::threshold(62.5));
        grid.push(ScalerSpec::appdata_windowed(3, 90.5));
        grid.push(ScalerSpec::predictive(45.5));
        grid.extend(ScalerSpec::appdata_sweep(0.99999));
        grid.push(ScalerSpec::composite(
            ScalerSpec::threshold(80.0),
            ScalerSpec::appdata_windowed(3, 240.0),
        ));
        grid.push(ScalerSpec::depas(0.7, 0.1, 0.5));
        grid.push(ScalerSpec::depas(0.5, 0.25, 1.0));
        grid.push(ScalerSpec::depas(0.8, 0.05, 0.25));
        grid.push(ScalerSpec::composite(
            ScalerSpec::depas(0.7, 0.1, 0.5),
            ScalerSpec::appdata(2),
        ));
        grid.push(ScalerSpec::queueing(0.7, 0.5));
        grid.push(ScalerSpec::queueing(0.5, 1.0));
        grid.push(ScalerSpec::queueing(0.85, 0.25));
        grid.push(ScalerSpec::pid(2.0, 0.5, 0.25));
        grid.push(ScalerSpec::pid(1.5, 0.0, 0.0));
        grid.push(ScalerSpec::pid(4.0, 0.05, 1.0));
        grid.push(ScalerSpec::hybrid(80.0, 120.0));
        grid.push(ScalerSpec::hybrid(62.5, 90.5));
        grid.push(ScalerSpec::composite(
            ScalerSpec::queueing(0.7, 0.5),
            ScalerSpec::appdata(2),
        ));
        grid.push(ScalerSpec::composite(
            ScalerSpec::pid(2.0, 0.5, 0.25),
            ScalerSpec::appdata(3),
        ));
        grid.push(ScalerSpec::composite(
            ScalerSpec::hybrid(80.0, 120.0),
            ScalerSpec::appdata(1),
        ));
        grid
    }

    #[test]
    fn every_variant_constructs_and_name_matches_spec_string() {
        let model = DelayModel::default();
        for spec in registry_grid() {
            let scaler = spec.build(&model, mix());
            assert_eq!(scaler.name(), spec.to_string(), "spec {spec:?}");
        }
    }

    #[test]
    fn string_form_round_trips() {
        for spec in registry_grid() {
            let s = spec.to_string();
            let back = ScalerSpec::parse(&s).unwrap_or_else(|e| panic!("{s:?}: {e}"));
            assert_eq!(back, spec, "{s:?}");
            assert_eq!(back.to_string(), s);
        }
    }

    #[test]
    fn parses_legacy_and_relaxed_forms() {
        assert_eq!(ScalerSpec::parse("threshold-80").unwrap(), ScalerSpec::threshold(80.0));
        assert_eq!(ScalerSpec::parse("load-0.99999").unwrap(), ScalerSpec::load(0.99999));
        assert_eq!(ScalerSpec::parse("vertical").unwrap(), ScalerSpec::Vertical);
        assert_eq!(
            ScalerSpec::parse(" load-q90% ").unwrap(),
            ScalerSpec::load(0.9),
        );
    }

    #[test]
    fn composite_parse_binds_first_valid_split() {
        let spec = ScalerSpec::parse("load-q99.999%+appdata+4").unwrap();
        assert_eq!(spec, ScalerSpec::load_plus_appdata(0.99999, 4));
        // three-way chains associate to the right
        let chain = ScalerSpec::parse("threshold-80%+appdata+1+appdata+2").unwrap();
        assert_eq!(
            chain,
            ScalerSpec::composite(
                ScalerSpec::threshold(80.0),
                ScalerSpec::composite(ScalerSpec::appdata(1), ScalerSpec::appdata(2)),
            )
        );
    }

    #[test]
    fn garbage_rejected_with_algorithm_error() {
        for bad in [
            "magic-9000",
            "threshold-500%",
            "load-q0%",
            "appdata+0",
            "",
            "+",
            "load-",
            "depas-0.7-0.1",       // missing gamma
            "depas-0.7-0.4-0.5",   // band ≥ min(T, 1−T)
            "depas-1.5-0.1-0.5",   // target out of (0,1)
            "depas-0.7-0.1-2",     // gamma out of (0,1]
            "depas-0.7-0.1-0.5-9", // trailing component
            "queueing-0.7",        // missing wait fraction
            "queueing-1.5-0.5",    // rho out of (0,1)
            "queueing-0.7-0",      // w_frac out of (0,1]
            "queueing-0.7-0.5-9",  // trailing component
            "pid-2-0.5",           // missing kd
            "pid-0-0.5-0.25",      // kp out of (0,inf)
            "pid-2--1-0.25",       // negative ki
            "pid-2-0.5-0.25-9",    // trailing component
            "hybrid-80",           // missing horizon
            "hybrid-150-120",      // threshold out of (0,100]
            "hybrid-80-0",         // non-positive horizon
            "hybrid-80-120-9",     // trailing component
        ] {
            let err = ScalerSpec::parse(bad).unwrap_err();
            assert!(
                format!("{err}").contains("unknown algorithm"),
                "{bad:?}: {err}"
            );
        }
    }

    #[test]
    fn built_scalers_match_direct_construction() {
        let model = DelayModel::default();
        // The spec path must not perturb parameters (exact float equality).
        let via_spec = ScalerSpec::load(0.99999).build(&model, mix());
        let direct = LoadScaler::new(model.clone(), 0.99999, mix());
        assert_eq!(via_spec.name(), crate::autoscale::AutoScaler::name(&direct));
        let thr = ScalerSpec::threshold(60.0).build(&model, mix());
        assert_eq!(thr.name(), "threshold-60%");
    }
}
