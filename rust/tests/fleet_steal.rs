//! Work-stealing fleet integration tests: the property the scheduler
//! stakes everything on is that *any* interleaving of claims, crashes,
//! steals and re-runs merges bit-identically to the unsharded serial
//! run, with each plan key exactly once in the merged table. The grid is
//! deliberately uneven (trace volumes and replication budgets differ per
//! row) so the LPT cost ordering actually reorders execution.

use sla_autoscale::autoscale::ScalerSpec;
use sla_autoscale::config::SimConfig;
use sla_autoscale::scenario::{
    merge_records, merged_results, read_journal_dir, run_stealing, Overrides, ScenarioMatrix,
    ScenarioResult, StealConfig, TraceSource,
};
use sla_autoscale::util::TempDir;
use sla_autoscale::workload::MatchSpec;
use std::time::Duration;

/// A grid with wildly uneven rows: three trace volumes (9k / 3k / 1.5k
/// tweets) crossed with two scalers, and a bumped replication budget on
/// the biggest trace's rows so predicted costs spread by ~12x.
fn uneven_matrix() -> ScenarioMatrix {
    let spec = |opponent: &'static str, total_tweets: u64| MatchSpec {
        opponent,
        date: "—",
        total_tweets,
        length_hours: 0.2,
        events: vec![],
    };
    let sources = [
        TraceSource::spec(spec("FleetBig", 9_000), false),
        TraceSource::spec(spec("FleetMid", 3_000), false),
        TraceSource::spec(spec("FleetSmall", 1_500), false),
    ];
    let scalers = [ScalerSpec::threshold(70.0), ScalerSpec::load(0.99)];
    let mut matrix = ScenarioMatrix::cross(
        &sources,
        &SimConfig::default(),
        &[Overrides::default()],
        &scalers,
        3,
    );
    // Uneven replication budgets: the big trace's rows get twice the
    // budget, stretching the cost spread the LPT order sorts by.
    for s in &mut matrix.scenarios {
        if s.source.label().contains("FleetBig") {
            s.max_reps = 6;
        }
    }
    matrix
}

fn assert_same(got: &ScenarioResult, want: &ScenarioResult) {
    assert_eq!(got.name, want.name);
    assert_eq!(got.reps, want.reps, "{}", got.name);
    assert_eq!(got.violation_pct.to_bits(), want.violation_pct.to_bits(), "{}", got.name);
    assert_eq!(got.cpu_hours.to_bits(), want.cpu_hours.to_bits(), "{}", got.name);
}

/// Three concurrent workers race claims over one journal dir; the merged
/// table is bit-identical to the serial run and holds each plan key
/// exactly once, no matter which worker won which row.
#[test]
fn stealing_fleet_matches_serial_bits() {
    let matrix = uneven_matrix();
    let serial = matrix.run_serial().unwrap();
    let plan = matrix.plan();
    let dir = TempDir::new().unwrap();
    let cfg = StealConfig::with_expiry(Duration::from_secs(30));
    let outcomes = std::thread::scope(|s| {
        let workers: Vec<_> = (0..3)
            .map(|_| s.spawn(|| run_stealing(&matrix, 1, dir.path(), None, &cfg).unwrap()))
            .collect();
        workers.into_iter().map(|w| w.join().unwrap()).collect::<Vec<_>>()
    });
    // Every row ran somewhere; with a 30 s expiry nothing looked stale.
    let total_ran: usize = outcomes.iter().map(|o| o.ran).sum();
    assert!(total_ran >= plan.len(), "fleet ran {total_ran} of {} rows", plan.len());
    assert!(outcomes.iter().all(|o| !o.crashed));
    // Exactly-once in the *merged* table (duplicates dedupe by key).
    let keys: std::collections::HashSet<u64> = plan.jobs.iter().map(|j| j.key).collect();
    let records: Vec<_> = read_journal_dir(dir.path())
        .unwrap()
        .into_iter()
        .filter(|r| keys.contains(&r.key))
        .collect();
    let merged = merge_records(records).unwrap();
    assert_eq!(merged.len(), plan.len(), "each key exactly once after the merge");
    let results = merged_results(&matrix, dir.path()).unwrap();
    assert_eq!(results.len(), serial.len());
    for (got, want) in results.iter().zip(&serial) {
        assert_same(got, want);
    }
    // No lease files survive a drained plan.
    let leases: Vec<String> = std::fs::read_dir(dir.path())
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .filter(|n| n.ends_with(".lease"))
        .collect();
    assert!(leases.is_empty(), "{leases:?}");
}

/// Crash-recovery property, swept over the crash point: worker A dies
/// after k jobs while holding one more unreleased lease; worker B steals
/// the stale lease and drains the rest. For every k the merged table is
/// bit-identical to the serial run.
#[test]
fn crashed_workers_leases_are_stolen_and_the_merge_still_matches_serial() {
    let matrix = uneven_matrix();
    let serial = matrix.run_serial().unwrap();
    let plan = matrix.plan();
    for k in [0usize, 1, 2] {
        let dir = TempDir::new().unwrap();
        let mut crash_cfg = StealConfig::with_expiry(Duration::from_millis(150));
        crash_cfg.crash_after = Some(k);
        let a = run_stealing(&matrix, 1, dir.path(), None, &crash_cfg).unwrap();
        assert!(a.crashed, "crash hook must fire (k = {k})");
        assert_eq!(a.ran, k, "the crashing worker runs exactly k jobs first");
        let abandoned: Vec<String> = std::fs::read_dir(dir.path())
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".lease"))
            .collect();
        assert_eq!(abandoned.len(), 1, "the kill leaves one unreleased lease: {abandoned:?}");
        // Let the abandoned lease's heartbeat go stale, then recover.
        std::thread::sleep(Duration::from_millis(300));
        let b = run_stealing(
            &matrix,
            2,
            dir.path(),
            None,
            &StealConfig::with_expiry(Duration::from_millis(150)),
        )
        .unwrap();
        assert!(b.stolen >= 1, "worker B must steal the abandoned lease (k = {k})");
        assert_eq!(a.ran + b.ran, plan.len(), "A and B cover the plan between them (k = {k})");
        let results = merged_results(&matrix, dir.path()).unwrap();
        for (got, want) in results.iter().zip(&serial) {
            assert_same(got, want);
        }
    }
}

/// The LPT-ordered in-process paths (serial streaming and the shared
/// claim cursor) still produce row-ordered, bit-identical tables.
#[test]
fn lpt_ordered_matrix_run_is_bit_identical_to_serial() {
    let matrix = uneven_matrix();
    let serial = matrix.run_serial().unwrap();
    let threaded = matrix.run(2).unwrap();
    assert_eq!(serial.len(), threaded.len());
    for (got, want) in threaded.iter().zip(&serial) {
        assert_same(got, want);
    }
}
