//! Integration: the paper's qualitative claims (the "shape" each
//! table/figure must show) checked end-to-end on fast-mode statistical
//! replicas.

use sla_autoscale::autoscale::{AppdataScaler, Composite, LoadScaler, ThresholdScaler};
use sla_autoscale::config::SimConfig;
use sla_autoscale::delay::DelayModel;
use sla_autoscale::experiments::common::{default_mix, scale_config, trace_for};
use sla_autoscale::experiments::{fig7, fig8, table1};
use sla_autoscale::sim::Simulator;
use sla_autoscale::workload::by_opponent;

/// §V-A: "both the threshold and the load algorithms performed perfectly
/// for both matches" (England, France) — no SLA violations on friendlies.
#[test]
fn friendlies_are_violation_free() {
    for opponent in ["England", "France"] {
        let spec = by_opponent(opponent).unwrap();
        let trace = trace_for(&spec, true);
        let cfg = scale_config(&SimConfig::default(), true);
        let model = DelayModel::default();
        for scaler in [
            Box::new(ThresholdScaler::new(0.60)) as Box<dyn sla_autoscale::autoscale::AutoScaler>,
            Box::new(LoadScaler::new(model.clone(), 0.99999, default_mix())),
        ] {
            let name = scaler.name();
            let res = Simulator::new(&cfg, &model).run(&trace, scaler);
            assert!(
                res.violation_pct() < 0.05,
                "{opponent} under {name}: {:.3}% violations",
                res.violation_pct()
            );
        }
    }
}

/// §V-A: load cost is ~flat across quantiles ("cost differences for
/// different quantiles is insignificant").
#[test]
fn load_cost_flat_in_quantile() {
    let spec = by_opponent("Italy").unwrap();
    let results = fig7::run_match(&spec, true, 3);
    let costs: Vec<f64> = results
        .iter()
        .filter(|r| r.name.starts_with("load"))
        .map(|r| r.cpu_hours)
        .collect();
    let (lo, hi) = costs.iter().fold((f64::MAX, f64::MIN), |(l, h), &c| (l.min(c), h.max(c)));
    assert!(
        (hi - lo) / lo < 0.15,
        "load cost spread too wide: {lo:.2}..{hi:.2} CPU-h"
    );
}

/// §V-A headline: replacing threshold-60% with load on the big matches
/// saves a large fraction of CPU-hours (paper: 43% Uruguay, 33% Spain).
#[test]
fn load_saves_cpu_hours_on_finals() {
    for (opponent, min_saving) in [("Uruguay", 0.15), ("Spain", 0.15)] {
        let spec = by_opponent(opponent).unwrap();
        let results = fig7::run_match(&spec, true, 3);
        let thr60 = results.iter().find(|r| r.name == "threshold-60%").unwrap();
        let load = results.iter().find(|r| r.name == "load-q99.999%").unwrap();
        let saving = 1.0 - load.cpu_hours / thr60.cpu_hours;
        assert!(
            saving > min_saving,
            "{opponent}: load saves only {:.0}% (paper: 33-43%)",
            saving * 100.0
        );
    }
}

/// Fig 8 / abstract headline: appdata cuts SLA violations by ~95% versus
/// the threshold algorithm (paper: 95.24%), improves on load alone
/// (paper: 92.81% there; our load baseline is stronger so the relative
/// headroom is smaller), and costs less than
/// threshold-60% while doing so.
#[test]
fn appdata_reduces_violations_substantially() {
    let results = fig8::run_spain(true, 3);
    let load = results.iter().find(|r| r.name == "load-only").unwrap();
    let thr = results.iter().find(|r| r.name == "threshold-60%").unwrap();
    let best = results
        .iter()
        .filter(|r| r.name.starts_with("appdata"))
        .min_by(|a, b| a.violation_pct.total_cmp(&b.violation_pct))
        .unwrap();
    assert!(thr.violation_pct > 0.0, "Spain must stress the threshold algorithm");
    let vs_thr = 1.0 - best.violation_pct / thr.violation_pct;
    assert!(
        vs_thr > 0.80,
        "appdata best {:.2}% vs threshold-60% {:.2}% — only {:.0}% (paper: 95.24%)",
        best.violation_pct,
        thr.violation_pct,
        vs_thr * 100.0
    );
    // appdata never does worse than load alone (it only adds capacity)
    assert!(
        best.violation_pct <= load.violation_pct + 0.02,
        "appdata best {:.3}% worse than load {:.3}%",
        best.violation_pct,
        load.violation_pct
    );
}

/// Table I shape: correlation high at lag 0, still clearly positive at
/// lag 10, monotone-ish decay (paper: 0.79 → 0.70).
#[test]
fn table1_correlation_shape() {
    let c = table1::correlations(true);
    assert!(c[0] > 0.60, "lag0 = {}", c[0]);
    assert!(c[10] > 0.30, "lag10 = {}", c[10]);
    assert!(c[0] > c[10]);
    // no wild sign flips anywhere
    assert!(c.iter().all(|&r| r > 0.0), "{c:?}");
}

/// Mexico's abrupt peak (§V-A): the load algorithm's multi-CPU upscaling
/// beats the threshold algorithm's one-at-a-time on quality for at least
/// one threshold setting, at lower cost for all.
#[test]
fn mexico_peak_favors_load() {
    let spec = by_opponent("Mexico").unwrap();
    let results = fig7::run_match(&spec, true, 3);
    let load_best = results
        .iter()
        .filter(|r| r.name.starts_with("load"))
        .min_by(|a, b| a.violation_pct.total_cmp(&b.violation_pct))
        .unwrap();
    let thr_high = results.iter().find(|r| r.name == "threshold-99%").unwrap();
    assert!(
        load_best.violation_pct <= thr_high.violation_pct + 0.05,
        "load best {:.2}% vs threshold-99% {:.2}%",
        load_best.violation_pct,
        thr_high.violation_pct
    );
}

/// Full-campaign determinism: the same seed reproduces identical results.
#[test]
fn campaign_determinism() {
    let spec = by_opponent("Japan").unwrap();
    let trace = trace_for(&spec, true);
    let cfg = scale_config(&SimConfig::default(), true);
    let model = DelayModel::default();
    let run = || {
        Simulator::new(&cfg, &model).run(
            &trace,
            Box::new(Composite::new(
                LoadScaler::new(model.clone(), 0.99999, default_mix()),
                AppdataScaler::new(4),
            )),
        )
    };
    let (a, b) = (run(), run());
    assert_eq!(a.history.violations(), b.history.violations());
    assert_eq!(a.cpu_hours, b.cpu_hours);
    assert_eq!(a.decisions.len(), b.decisions.len());
}
