//! Determinism-lint engine integration tests, driven by the fixture
//! corpus under `rust/tests/lint_fixtures/`. Fixtures are plain data —
//! test targets are explicit in Cargo.toml, so nothing here compiles
//! them — and each one either violates exactly one rule, passes the
//! near-miss variant of the same construct, or exercises suppression
//! and pragma-hygiene paths.

use sla_autoscale::analysis::{lint_paths, parse_json, render_human, render_json, LintReport};
use std::path::PathBuf;

fn fixture(rel: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/lint_fixtures").join(rel)
}

fn lint_fixture(rel: &str) -> LintReport {
    lint_paths(&[fixture(rel)]).unwrap_or_else(|e| panic!("linting {rel}: {e}"))
}

fn rules_of(report: &LintReport) -> Vec<&str> {
    report.findings.iter().map(|f| f.rule.as_str()).collect()
}

#[test]
fn violating_fixtures_fire_their_rule() {
    for (rel, rule, line) in [
        ("det001_violation.rs", "DET-001", 6),
        ("det003_violation.rs", "DET-003", 4),
        ("det004_violation.rs", "DET-004", 5),
        ("scenario/det002_violation.rs", "DET-002", 8),
        ("scenario/det006_violation.rs", "DET-006", 4),
    ] {
        let report = lint_fixture(rel);
        assert_eq!(rules_of(&report), vec![rule], "{rel}");
        assert_eq!(report.findings[0].line, line, "{rel}");
        assert!(!report.findings[0].invariant.is_empty(), "{rel} carries invariant text");
    }
}

#[test]
fn passing_fixtures_are_clean() {
    for rel in [
        "det001_ok.rs",
        "det003_ok.rs",
        "det004_ok.rs",
        "scenario/det002_ok.rs",
        "scenario/det005_ok.rs",
        "scenario/det006_ok.rs",
    ] {
        let report = lint_fixture(rel);
        assert!(report.is_clean(), "{rel}: {:?}", report.findings);
        assert!(report.allowed.is_empty(), "{rel} needs no suppressions");
    }
}

#[test]
fn hash_order_float_sum_fires_both_rules() {
    let report = lint_fixture("scenario/det005_violation.rs");
    let rules = rules_of(&report);
    assert!(rules.contains(&"DET-005"), "rules: {rules:?}");
    assert!(rules.contains(&"DET-002"), "the iteration itself is also flagged: {rules:?}");
    for f in &report.findings {
        assert_eq!(f.line, 7, "both anchor on the accumulation line");
    }
}

#[test]
fn suppressions_silence_findings_and_surface_reasons() {
    let report = lint_fixture("suppressed_ok.rs");
    assert!(report.is_clean(), "findings: {:?}", report.findings);
    assert_eq!(report.allowed.len(), 2, "trailing and standalone pragma forms both apply");
    assert_eq!(report.allowed[0].line, 6);
    assert_eq!(report.allowed[1].line, 11);
    for a in &report.allowed {
        assert_eq!(a.rule, "DET-001");
        assert!(a.reason.starts_with("fixture:"), "reason surfaced verbatim: {:?}", a.reason);
    }
}

#[test]
fn malformed_pragmas_become_det000_and_do_not_suppress() {
    let report = lint_fixture("bad_pragma.rs");
    assert_eq!(rules_of(&report), vec!["DET-000", "DET-001", "DET-000"]);
    assert_eq!(report.findings[0].line, 4, "missing reason");
    assert_eq!(report.findings[1].line, 6, "the broken pragma suppressed nothing");
    assert_eq!(report.findings[2].line, 9, "unknown rule id");
    assert!(report.allowed.is_empty());
}

#[test]
fn corpus_walk_is_deterministic_and_json_round_trips() {
    let root = fixture("");
    let report = lint_paths(&[root.clone()]).unwrap();
    assert_eq!(report.files_scanned, 14);
    assert_eq!(report.findings.len(), 10);
    assert_eq!(report.allowed.len(), 2);
    let sorted = report
        .findings
        .windows(2)
        .all(|w| (&w[0].file, w[0].line, &w[0].rule) <= (&w[1].file, w[1].line, &w[1].rule));
    assert!(sorted, "findings sorted by (file, line, rule)");

    let again = lint_paths(&[root]).unwrap();
    assert_eq!(render_json(&report), render_json(&again), "byte-identical across runs");

    let parsed = parse_json(&render_json(&report)).unwrap();
    assert_eq!(parsed, report, "JSON round-trip preserves every field");
}

#[test]
fn human_report_names_rule_file_line_and_invariant() {
    let report = lint_fixture("det001_violation.rs");
    let text = render_human(&report);
    assert!(text.contains("DET-001"), "{text}");
    assert!(text.contains("det001_violation.rs:6"), "{text}");
    assert!(text.contains("invariant:"), "{text}");
    assert!(text.contains("1 finding(s)"), "{text}");
}
