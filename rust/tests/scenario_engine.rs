//! Integration tests for the scenario engine: determinism of the parallel
//! runner vs the serial reference path, trace-cache sharing, and the
//! scaler registry driving real simulations.

use sla_autoscale::autoscale::ScalerSpec;
use sla_autoscale::config::SimConfig;
use sla_autoscale::delay::DelayModel;
use sla_autoscale::scenario::{run_replications, Overrides, ScenarioMatrix, TraceSource};
use sla_autoscale::workload::{GeneratorConfig, MatchSpec};
use std::sync::{Arc, Mutex};

fn small_source(total: u64) -> TraceSource {
    TraceSource::spec(
        MatchSpec {
            opponent: "EngineIT",
            date: "—",
            total_tweets: total,
            length_hours: 0.25,
            events: vec![],
        },
        false,
    )
}

fn mix() -> [f64; 3] {
    [0.30, 0.30, 0.40]
}

/// The headline determinism guarantee: for a fixed seed set, the parallel
/// replication path produces bit-identical `violation_pct` / `cpu_hours`
/// (and the same rep count) as the serial path, for every scaler family.
#[test]
fn parallel_replications_bit_identical_to_serial() {
    let trace = small_source(40_000).load().unwrap();
    let cfg = SimConfig { sla_secs: 60.0, ..Default::default() };
    let model = DelayModel::default();
    let specs = [
        ScalerSpec::threshold(70.0),
        ScalerSpec::load(0.99),
        ScalerSpec::load_plus_appdata(0.99999, 2),
        ScalerSpec::predictive(120.0),
        ScalerSpec::Vertical,
        ScalerSpec::depas(0.7, 0.1, 0.5),
    ];
    for spec in &specs {
        let serial = run_replications(
            &trace, &cfg, &model, spec, mix(), spec.to_string(), 6, 1,
        );
        for wave in [2, 4, 8] {
            let par = run_replications(
                &trace, &cfg, &model, spec, mix(), spec.to_string(), 6, wave,
            );
            assert_eq!(serial.reps, par.reps, "{spec} wave={wave}");
            assert_eq!(
                serial.violation_pct.to_bits(),
                par.violation_pct.to_bits(),
                "{spec} wave={wave}: {} vs {}",
                serial.violation_pct,
                par.violation_pct
            );
            assert_eq!(
                serial.cpu_hours.to_bits(),
                par.cpu_hours.to_bits(),
                "{spec} wave={wave}: {} vs {}",
                serial.cpu_hours,
                par.cpu_hours
            );
        }
    }
}

/// The rate-limited queue regime (`input_rate` caps admissions per step)
/// disables the idle fast-forward and exercises the shared input queue,
/// so it gets its own bit-identity check through the batched wave path.
#[test]
fn rate_limited_replications_bit_identical_to_serial() {
    let trace = small_source(20_000).load().unwrap();
    let cfg = SimConfig { input_rate: Some(60.0), sla_secs: 90.0, ..Default::default() };
    let model = DelayModel::default();
    for spec in [ScalerSpec::threshold(70.0), ScalerSpec::load(0.99)] {
        let serial = run_replications(
            &trace, &cfg, &model, &spec, mix(), spec.to_string(), 5, 1,
        );
        for wave in [2, 5] {
            let par = run_replications(
                &trace, &cfg, &model, &spec, mix(), spec.to_string(), 5, wave,
            );
            assert_eq!(serial.reps, par.reps, "{spec} wave={wave}");
            assert_eq!(serial.violation_pct.to_bits(), par.violation_pct.to_bits(), "{spec}");
            assert_eq!(serial.cpu_hours.to_bits(), par.cpu_hours.to_bits(), "{spec}");
        }
    }
}

/// Whole-matrix determinism: threaded execution returns the same rows in
/// the same order as the serial path.
#[test]
fn matrix_parallel_matches_serial() {
    let cfg = SimConfig::default();
    let sources = [small_source(25_000), small_source(12_000)];
    let scalers = [ScalerSpec::threshold(60.0), ScalerSpec::load(0.99999)];
    let matrix = ScenarioMatrix::cross(
        &sources,
        &cfg,
        &[Overrides::default()],
        &scalers,
        4,
    );
    let serial = matrix.run_serial().unwrap();
    let parallel = matrix.run(4).unwrap();
    assert_eq!(serial.len(), parallel.len());
    for (s, p) in serial.iter().zip(&parallel) {
        assert_eq!(s.name, p.name);
        assert_eq!(s.reps, p.reps, "{}", s.name);
        assert_eq!(s.violation_pct.to_bits(), p.violation_pct.to_bits(), "{}", s.name);
        assert_eq!(s.cpu_hours.to_bits(), p.cpu_hours.to_bits(), "{}", s.name);
    }
}

/// Config overrides are a real grid axis: a tighter SLA must not improve
/// (and in an overloaded setting worsens) the violation percentage.
#[test]
fn override_axis_changes_outcomes() {
    let cfg = SimConfig::default();
    let overrides = [
        Overrides { sla_secs: Some(300.0), ..Default::default() },
        Overrides { sla_secs: Some(5.0), ..Default::default() },
    ];
    let matrix = ScenarioMatrix::cross(
        &[small_source(40_000)],
        &cfg,
        &overrides,
        &[ScalerSpec::threshold(99.0)],
        3,
    );
    let results = matrix.run(2).unwrap();
    assert_eq!(results.len(), 2);
    assert!(
        results[1].violation_pct >= results[0].violation_pct,
        "5 s SLA ({:.2}%) cannot beat 300 s SLA ({:.2}%)",
        results[1].violation_pct,
        results[0].violation_pct
    );
}

/// Each distinct trace is generated once per process and shared.
#[test]
fn matrix_rows_share_cached_traces() {
    let src = small_source(8_000);
    let a = src.load().unwrap();
    let b = src.load().unwrap();
    assert!(Arc::ptr_eq(&a, &b));
    // and the experiments' trace_for goes through the same cache
    let spec = sla_autoscale::workload::by_opponent("Japan").unwrap();
    let x = sla_autoscale::experiments::common::trace_for(&spec, true);
    let y = TraceSource::opponent("Japan", true).load().unwrap();
    assert!(Arc::ptr_eq(&x, &y), "trace_for and TraceSource must share the cache");
}

/// The workload-shape axis end to end: a grid sweeping two generator
/// configs over one spec gets two *distinct* traces (the cache key
/// includes the generator fingerprint — regression for the aliasing
/// bug), and streamed results carry exactly the batch content,
/// independent of completion order.
#[test]
fn generator_axis_streams_batch_identical_results() {
    let source = TraceSource::spec(
        MatchSpec {
            opponent: "GenAxisIT",
            date: "—",
            total_tweets: 15_000,
            length_hours: 0.25,
            events: vec![],
        },
        false,
    );
    let gens = [
        GeneratorConfig::default(),
        GeneratorConfig { lead_min: 0.0, ..GeneratorConfig::default() },
    ];
    let matrix = ScenarioMatrix::cross_gen(
        &[source],
        &gens,
        &SimConfig::default(),
        &[Overrides::default()],
        &[ScalerSpec::load(0.99), ScalerSpec::load_plus_appdata(0.99999, 2)],
        3,
    );
    assert_eq!(matrix.len(), 4);

    // Distinct traces across the generator axis, shared within a shape.
    let t0 = matrix.scenarios[0].source.load().unwrap();
    let t1 = matrix.scenarios[1].source.load().unwrap();
    let t2 = matrix.scenarios[2].source.load().unwrap();
    assert!(Arc::ptr_eq(&t0, &t1), "same shape shares one trace");
    assert!(!Arc::ptr_eq(&t0, &t2), "different generator configs must not alias");

    let batch = matrix.run_serial().unwrap();
    let streamed: Mutex<Vec<(usize, String, u64, u64, usize)>> = Mutex::new(Vec::new());
    let parallel = matrix
        .run_with(4, |i, r| {
            streamed.lock().unwrap().push((
                i,
                r.name.clone(),
                r.violation_pct.to_bits(),
                r.cpu_hours.to_bits(),
                r.reps,
            ));
        })
        .unwrap();
    let mut streamed = streamed.into_inner().unwrap();
    streamed.sort_by_key(|(i, ..)| *i);
    assert_eq!(streamed.len(), batch.len());
    for (got, want) in streamed.iter().zip(&batch) {
        assert_eq!(got.1, want.name);
        assert_eq!(got.2, want.violation_pct.to_bits(), "{}", want.name);
        assert_eq!(got.3, want.cpu_hours.to_bits(), "{}", want.name);
        assert_eq!(got.4, want.reps, "{}", want.name);
    }
    for (p, want) in parallel.iter().zip(&batch) {
        assert_eq!(p.name, want.name);
        assert_eq!(p.violation_pct.to_bits(), want.violation_pct.to_bits());
    }
}

/// Registry specs drive real simulations end to end (every family).
#[test]
fn registry_specs_simulate_end_to_end() {
    let trace = small_source(12_000).load().unwrap();
    let cfg = SimConfig::default();
    let model = DelayModel::default();
    for spec_str in [
        "threshold-80%",
        "load-q99.999%",
        "load-q99.999%+appdata+3",
        "predictive-h120s",
        "vertical-ladder",
        "threshold-90%+appdata+2@w60",
        "depas-0.7-0.1-0.5",
        "depas-0.7-0.1-0.5+appdata+2",
        "queueing-0.7-0.5",
        "pid-2-0.5-0.25",
        "hybrid-80-120",
        "queueing-0.7-0.5+appdata+2",
        "pid-2-0.5-0.25+appdata+3@w60",
    ] {
        let spec = ScalerSpec::parse(spec_str).unwrap();
        let r = run_replications(
            &trace, &cfg, &model, &spec, mix(), spec.to_string(), 3, 2,
        );
        assert_eq!(r.name, spec_str, "name survives the round trip");
        assert!(r.cpu_hours > 0.0, "{spec_str}");
        assert!(r.reps >= 3, "{spec_str}");
    }
}

/// The gauntlet's three new families (queueing / PID / hybrid) under the
/// headline determinism guarantee, including the new SLA metrics: serial
/// and wide waves agree bit for bit on `violation_pct`, `cpu_hours`,
/// `p99_delay` and `sla_score`.
#[test]
fn gauntlet_families_bit_identical_to_serial() {
    let trace = small_source(30_000).load().unwrap();
    let cfg = SimConfig { sla_secs: 60.0, ..Default::default() };
    let model = DelayModel::default();
    let specs = [
        ScalerSpec::queueing(0.7, 0.5),
        ScalerSpec::pid(2.0, 0.5, 0.25),
        ScalerSpec::hybrid(80.0, 120.0),
    ];
    for spec in &specs {
        let serial = run_replications(
            &trace, &cfg, &model, spec, mix(), spec.to_string(), 5, 1,
        );
        assert!(serial.p99_delay >= 0.0, "{spec}");
        assert!(serial.sla_score.is_finite(), "{spec}");
        for wave in [2, 5] {
            let par = run_replications(
                &trace, &cfg, &model, spec, mix(), spec.to_string(), 5, wave,
            );
            assert_eq!(serial.reps, par.reps, "{spec} wave={wave}");
            assert_eq!(
                serial.violation_pct.to_bits(),
                par.violation_pct.to_bits(),
                "{spec} wave={wave}"
            );
            assert_eq!(serial.cpu_hours.to_bits(), par.cpu_hours.to_bits(), "{spec} wave={wave}");
            assert_eq!(serial.p99_delay.to_bits(), par.p99_delay.to_bits(), "{spec} wave={wave}");
            assert_eq!(serial.sla_score.to_bits(), par.sla_score.to_bits(), "{spec} wave={wave}");
        }
    }
}

/// The adversarial fault axes as a matrix dimension: rows with failure
/// injection and boot-time jitter carry their labels, stay bit-identical
/// between the serial and threaded paths, and the injected chaos is real
/// (the faulty row's trajectory measurably diverges from the benign one).
#[test]
fn fault_axes_matrix_threaded_bit_identical_to_serial() {
    let cfg = SimConfig { sla_secs: 60.0, ..Default::default() };
    let overrides = [
        Overrides::default(),
        Overrides {
            failure_mtbf_secs: Some(900.0),
            boot_jitter_secs: Some(30.0),
            failure_seed: Some(11),
            ..Default::default()
        },
        Overrides { boot_jitter_secs: Some(30.0), ..Default::default() },
    ];
    let scalers = [ScalerSpec::threshold(70.0), ScalerSpec::queueing(0.7, 0.5)];
    let matrix = ScenarioMatrix::cross(
        &[small_source(30_000)],
        &cfg,
        &overrides,
        &scalers,
        4,
    );
    let serial = matrix.run_serial().unwrap();
    let threaded = matrix.run(8).unwrap();
    assert_eq!(serial.len(), threaded.len());
    for (s, p) in serial.iter().zip(&threaded) {
        assert_eq!(s.name, p.name);
        assert_eq!(s.reps, p.reps, "{}", s.name);
        assert_eq!(s.violation_pct.to_bits(), p.violation_pct.to_bits(), "{}", s.name);
        assert_eq!(s.cpu_hours.to_bits(), p.cpu_hours.to_bits(), "{}", s.name);
        assert_eq!(s.p99_delay.to_bits(), p.p99_delay.to_bits(), "{}", s.name);
        assert_eq!(s.sla_score.to_bits(), p.sla_score.to_bits(), "{}", s.name);
    }
    let benign = serial.iter().find(|r| r.name == "threshold-70%").unwrap();
    let chaos = serial
        .iter()
        .find(|r| r.name == "threshold-70%/mtbf=900s,boot=30s,fseed=11")
        .unwrap();
    assert_ne!(
        chaos.violation_pct.to_bits(),
        benign.violation_pct.to_bits(),
        "the fault axis must actually perturb the run"
    );
    assert!(
        serial.iter().any(|r| r.name == "queueing-0.7-0.5/boot=30s"),
        "boot-jitter-only rows must carry the boot label"
    );
}

/// The first scaler family with *per-node* decision logic must honor the
/// engine's headline guarantee: a threaded matrix run is bit-identical
/// to the serial path, across a fleet-size (starting_cpus) axis — DEPAS
/// votes are pure functions of (params, time, node ids), so no amount of
/// thread scheduling may perturb them.
#[test]
fn depas_matrix_threaded_bit_identical_to_serial() {
    let cfg = SimConfig::default();
    let overrides = [
        Overrides { starting_cpus: Some(1), ..Default::default() },
        Overrides { starting_cpus: Some(4), ..Default::default() },
    ];
    let scalers = [
        ScalerSpec::depas(0.7, 0.1, 0.5),
        ScalerSpec::depas(0.7, 0.05, 1.0),
        ScalerSpec::load(0.99999),
    ];
    let matrix = ScenarioMatrix::cross(
        &[small_source(30_000)],
        &cfg,
        &overrides,
        &scalers,
        4,
    );
    let serial = matrix.run_serial().unwrap();
    let threaded = matrix.run(8).unwrap();
    assert_eq!(serial.len(), threaded.len());
    for (s, p) in serial.iter().zip(&threaded) {
        assert_eq!(s.name, p.name);
        assert_eq!(s.reps, p.reps, "{}", s.name);
        assert_eq!(s.violation_pct.to_bits(), p.violation_pct.to_bits(), "{}", s.name);
        assert_eq!(s.cpu_hours.to_bits(), p.cpu_hours.to_bits(), "{}", s.name);
    }
    // the fleet axis is real: a larger starting fleet costs more CPU-hours
    let one = serial.iter().find(|r| r.name == "depas-0.7-0.1-0.5/cpus0=1").unwrap();
    let four = serial.iter().find(|r| r.name == "depas-0.7-0.1-0.5/cpus0=4").unwrap();
    assert!(four.cpu_hours > one.cpu_hours, "{} !> {}", four.cpu_hours, one.cpu_hours);
}
