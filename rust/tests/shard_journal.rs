//! Cross-process execution integration tests: deterministic sharding
//! (the union of `shard(i, n)` runs is bit-identical to the unsharded
//! serial run), journal resume (a truncated journal re-runs only the
//! lost rows), and shard-journal merge (the folded table equals the
//! single-process one, row for row, bit for bit).

use sla_autoscale::autoscale::ScalerSpec;
use sla_autoscale::config::SimConfig;
use sla_autoscale::scenario::sink::JOURNAL_HEADER_LEN;
use sla_autoscale::scenario::{
    merge_records, read_journal, read_journal_dir, run_plan, CollectSink, JournalSink, Overrides,
    ScenarioMatrix, ScenarioResult, TraceSource,
};
use sla_autoscale::util::TempDir;
use sla_autoscale::workload::MatchSpec;
use std::collections::HashSet;

fn small_matrix() -> ScenarioMatrix {
    let source = TraceSource::spec(
        MatchSpec {
            opponent: "ShardIT",
            date: "—",
            total_tweets: 12_000,
            length_hours: 0.25,
            events: vec![],
        },
        false,
    );
    let overrides = [
        Overrides::default(),
        Overrides { sla_secs: Some(60.0), ..Default::default() },
    ];
    let scalers = [
        ScalerSpec::threshold(70.0),
        ScalerSpec::load(0.99),
        ScalerSpec::load_plus_appdata(0.99999, 2),
    ];
    ScenarioMatrix::cross(&[source], &SimConfig::default(), &overrides, &scalers, 4)
}

fn assert_same(got: &ScenarioResult, want: &ScenarioResult) {
    assert_eq!(got.name, want.name);
    assert_eq!(got.reps, want.reps, "{}", got.name);
    assert_eq!(got.violation_pct.to_bits(), want.violation_pct.to_bits(), "{}", got.name);
    assert_eq!(got.cpu_hours.to_bits(), want.cpu_hours.to_bits(), "{}", got.name);
    assert_eq!(got.p99_delay.to_bits(), want.p99_delay.to_bits(), "{}", got.name);
    assert_eq!(got.sla_score.to_bits(), want.sla_score.to_bits(), "{}", got.name);
}

/// The headline sharding guarantee: for n in {2, 3}, serial or threaded,
/// the union of all shards reproduces the unsharded serial run exactly —
/// same `violation_pct`, `cpu_hours`, and replication counts per row.
#[test]
fn shard_union_is_bit_identical_to_the_unsharded_run() {
    let matrix = small_matrix();
    let full = matrix.run_serial().unwrap();
    let plan = matrix.plan();
    for n in [2, 3] {
        for threads in [1, 4] {
            let mut merged: Vec<Option<ScenarioResult>> = vec![None; plan.len()];
            for i in 0..n {
                let shard = plan.shard(i, n).unwrap();
                let sink = CollectSink::new();
                let results = run_plan(&matrix, &shard.jobs, threads, &sink).unwrap();
                assert_eq!(results.len(), shard.jobs.len());
                for (job, res) in shard.jobs.iter().zip(results) {
                    assert!(merged[job.index].is_none(), "shards must be disjoint");
                    merged[job.index] = Some(res);
                }
            }
            for (slot, want) in merged.iter().zip(&full) {
                let got = slot.as_ref().expect("shards must cover every row");
                assert_same(got, want);
            }
        }
    }
}

/// Kill a journaled run "mid-matrix" by truncating the journal after k
/// records: the resumed run counts k job-key hits, re-simulates only the
/// lost rows, and the merged table equals the clean run bit for bit.
#[test]
fn truncated_journal_resumes_without_resimulating() {
    let matrix = small_matrix();
    let plan = matrix.plan();
    let clean = matrix.run_serial().unwrap();
    let dir = TempDir::new().unwrap();
    let path = dir.join("run.journal");

    let (journal, prior) = JournalSink::open(&path).unwrap();
    assert!(prior.is_empty());
    run_plan(&matrix, &plan.jobs, 1, &journal).unwrap();
    drop(journal);
    assert_eq!(read_journal(&path).unwrap().len(), plan.len());

    // "Crash" after k records: walk the framing and cut the file there.
    let k = 2;
    let data = std::fs::read(&path).unwrap();
    let mut end = JOURNAL_HEADER_LEN;
    for _ in 0..k {
        let len = u32::from_le_bytes(data[end..end + 4].try_into().unwrap()) as usize;
        end += 4 + len + 8;
    }
    assert!(end < data.len());
    std::fs::write(&path, &data[..end]).unwrap();

    let (journal, prior) = JournalSink::open(&path).unwrap();
    assert_eq!(prior.len(), k, "surviving records load back");
    let done: HashSet<u64> = prior.iter().map(|r| r.key).collect();
    let (todo, hits) = plan.pending(&done);
    assert_eq!(hits, k, "job-key hit counter must match the surviving records");
    assert_eq!(todo.len(), plan.len() - k, "only lost rows are re-simulated");
    let fresh = run_plan(&matrix, &todo.jobs, 2, &journal).unwrap();
    assert_eq!(fresh.len(), plan.len() - k);
    drop(journal);

    let merged = merge_records(read_journal(&path).unwrap()).unwrap();
    assert_eq!(merged.len(), clean.len());
    for (rec, want) in merged.iter().zip(&clean) {
        assert_same(&rec.result, want);
    }
}

/// Journals written before the v3 layout (which added the
/// `p99_delay`/`sla_score` fields) must be rejected outright — decoding
/// a v2 record as v3 would silently misalign every float, so the version
/// check is the only safe door.
#[test]
fn pre_v3_journals_are_rejected_not_misread() {
    use sla_autoscale::scenario::sink::{JOURNAL_MAGIC, JOURNAL_VERSION};
    assert_eq!(JOURNAL_VERSION, 3, "update this test alongside the format");
    let dir = TempDir::new().unwrap();
    let path = dir.join("old.journal");
    let mut header = Vec::with_capacity(JOURNAL_HEADER_LEN);
    header.extend_from_slice(&JOURNAL_MAGIC);
    header.extend_from_slice(&2u32.to_le_bytes());
    std::fs::write(&path, &header).unwrap();
    let err = read_journal(&path).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("format v2") && msg.contains("expected v3"), "{msg}");
    assert!(JournalSink::open(&path).is_err(), "open must not append to an old-format journal");
}

/// The adversarial fault axes ride the journal like any other override:
/// rows with failure injection and boot jitter journal under distinct
/// job keys, and every v3 metric folds back bit-identical to the
/// in-process run.
#[test]
fn fault_axis_rows_journal_and_merge_bit_identically() {
    let source = TraceSource::spec(
        MatchSpec {
            opponent: "ShardFaultIT",
            date: "—",
            total_tweets: 12_000,
            length_hours: 0.25,
            events: vec![],
        },
        false,
    );
    let overrides = [
        Overrides::default(),
        Overrides {
            failure_mtbf_secs: Some(900.0),
            boot_jitter_secs: Some(30.0),
            failure_seed: Some(11),
            ..Default::default()
        },
    ];
    let scalers = [ScalerSpec::threshold(70.0), ScalerSpec::queueing(0.7, 0.5)];
    let matrix =
        ScenarioMatrix::cross(&[source], &SimConfig::default(), &overrides, &scalers, 3);
    let plan = matrix.plan();
    let keys: HashSet<u64> = plan.jobs.iter().map(|j| j.key).collect();
    assert_eq!(keys.len(), plan.len(), "fault axes must feed the job key");
    let clean = matrix.run_serial().unwrap();
    let dir = TempDir::new().unwrap();
    let (journal, _) = JournalSink::open(&dir.join("faults.journal")).unwrap();
    run_plan(&matrix, &plan.jobs, 2, &journal).unwrap();
    drop(journal);
    let merged = merge_records(read_journal_dir(dir.path()).unwrap()).unwrap();
    assert_eq!(merged.len(), clean.len());
    for (rec, want) in merged.iter().zip(&clean) {
        assert_same(&rec.result, want);
    }
    assert!(
        merged.iter().any(|r| r.result.name.contains("mtbf=900s,boot=30s,fseed=11")),
        "fault rows must carry their labels through the journal"
    );
}

/// Two shard processes, two journal files, one directory: `merge` folds
/// them back into the canonical single-process table.
#[test]
fn shard_journals_merge_into_the_canonical_table() {
    let matrix = small_matrix();
    let plan = matrix.plan();
    let clean = matrix.run_serial().unwrap();
    let dir = TempDir::new().unwrap();
    for i in 0..2usize {
        let file = dir.join(&format!("shard-{i}of2.journal"));
        let (journal, _) = JournalSink::open(&file).unwrap();
        let shard = plan.shard(i, 2).unwrap();
        run_plan(&matrix, &shard.jobs, 2, &journal).unwrap();
    }
    let merged = merge_records(read_journal_dir(dir.path()).unwrap()).unwrap();
    assert_eq!(merged.len(), clean.len());
    for (rec, want) in merged.iter().zip(&clean) {
        assert_same(&rec.result, want);
    }
}
