//! Property-based tests (in-tree harness: seeded random generation over
//! many cases, shrink-free but reproducible — every failure prints the
//! case seed). Each property runs a few hundred randomized cases.

use sla_autoscale::rng::Rng;
use sla_autoscale::sim::cycles::{distribute, distribute_paper, PsSchedule};
use sla_autoscale::sim::{Cluster, InputQueue};
use sla_autoscale::stats::descriptive::{quantile, quantile_sorted};
use sla_autoscale::stats::ema::ema_series;
use sla_autoscale::stats::weibull::Weibull;
use sla_autoscale::util::FlatMeta;
use sla_autoscale::workload::{Trace, Tweet, TweetClass};

/// Run `cases` random trials of a property with reproducible sub-seeds.
fn for_all(cases: u64, seed: u64, mut prop: impl FnMut(&mut Rng, u64)) {
    let root = Rng::new(seed);
    for case in 0..cases {
        let mut rng = root.split(case + 1);
        prop(&mut rng, case);
    }
}

#[test]
fn prop_algorithm1_optimized_equals_paper() {
    for_all(500, 0xA160, |rng, case| {
        let n = rng.range(0, 60) as usize;
        let xs: Vec<f64> = (0..n).map(|_| rng.next_f64() * 200.0 + 0.001).collect();
        let budget = rng.next_f64() * 300.0;
        let mut a = xs.clone();
        let mut b = xs.clone();
        let oa = distribute_paper(budget, &mut a);
        let ob = distribute(budget, &mut b);
        let mut ca = oa.completed.clone();
        ca.sort_unstable();
        assert_eq!(ca, ob.completed, "case {case}: xs={xs:?} budget={budget}");
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert!((x - y).abs() < 1e-6, "case {case} idx {i}: {x} vs {y}");
        }
        assert!((oa.consumed - ob.consumed).abs() < 1e-6, "case {case}");
    });
}

#[test]
fn prop_algorithm1_invariants() {
    for_all(500, 0xA161, |rng, case| {
        let n = rng.range(1, 80) as usize;
        let xs: Vec<f64> = (0..n).map(|_| rng.next_f64() * 100.0 + 1e-9).collect();
        let budget = rng.next_f64() * 200.0;
        let before: f64 = xs.iter().sum();
        let mut r = xs.clone();
        let out = distribute(budget, &mut r);
        // conservation: consumed cycles equal the drop in remaining work
        let after: f64 = r.iter().sum();
        assert!((before - after - out.consumed).abs() < 1e-6, "case {case}");
        // never overspends the budget, never leaves negative work
        assert!(out.consumed <= budget + 1e-9, "case {case}");
        assert!(r.iter().all(|&v| v >= 0.0), "case {case}");
        // completed tweets are zeroed; survivors keep positive work
        for &i in &out.completed {
            assert_eq!(r[i], 0.0, "case {case} idx {i}");
        }
        for (i, &v) in r.iter().enumerate() {
            if !out.completed.contains(&i) {
                assert!(v > 0.0, "case {case} idx {i}: survivor with no work");
            }
        }
        // work-conserving: if anything remains, the full budget was used
        if r.iter().any(|&v| v > 0.0) {
            assert!((out.consumed - budget).abs() < 1e-6, "case {case}: left work but idle cycles");
        }
    });
}

/// Per-step equivalence of the virtual-time distributor against the
/// paper's executable spec over whole random episodes: same completion
/// sets, consumed cycles and remaining cycles (within 1e-6), including
/// adversarial cascade mixes (clusters of near-identical tiny costs whose
/// redistribution excess finishes whole chains within one step).
#[test]
fn prop_virtual_time_schedule_equals_paper_per_step() {
    for_all(300, 0xF1A5, |rng, case| {
        let mut ps = PsSchedule::new();
        let mut reference: Vec<f64> = Vec::new(); // dense remaining (spec side)
        let mut live: Vec<u32> = Vec::new(); // reference index -> slot
        let mut tags: Vec<f64> = Vec::new(); // slot -> finish tag
        let mut next_slot = 0u32;
        let steps = rng.range(1, 50);
        for step in 0..steps {
            // Arrivals: usually a few spread-out costs; sometimes an
            // adversarial cascade cluster of near-equal tiny costs.
            let cascade = rng.chance(0.3);
            let n_arr = if cascade { rng.range(3, 12) } else { rng.range(0, 6) };
            let base = rng.next_f64() * 1e-3 + 1e-6;
            for _ in 0..n_arr {
                let cycles = if cascade {
                    base * (1.0 + rng.next_f64() * 1e-6)
                } else {
                    rng.next_f64() * 100.0 + 0.01
                };
                tags.push(ps.insert(cycles, next_slot));
                reference.push(cycles);
                live.push(next_slot);
                next_slot += 1;
            }
            let budget = rng.next_f64() * 120.0;
            let out = distribute_paper(budget, &mut reference);
            let consumed = ps.step(budget);
            assert!(
                (consumed - out.consumed).abs() < 1e-6,
                "case {case} step {step}: consumed {consumed} vs {}",
                out.consumed
            );
            let mut want: Vec<u32> = out.completed.iter().map(|&j| live[j]).collect();
            want.sort_unstable();
            let mut got: Vec<u32> = ps.completed().to_vec();
            got.sort_unstable();
            assert_eq!(want, got, "case {case} step {step}: completion sets differ");
            // compact the spec side like the engine does
            let mut done = out.completed.clone();
            done.sort_unstable_by(|a, b| b.cmp(a));
            for j in done {
                reference.swap_remove(j);
                live.swap_remove(j);
            }
            // survivors' remaining cycles agree
            for (j, &slot) in live.iter().enumerate() {
                let rem = tags[slot as usize] - ps.offset();
                assert!(
                    (rem - reference[j]).abs() < 1e-6,
                    "case {case} step {step} slot {slot}: {rem} vs {}",
                    reference[j]
                );
            }
        }
    });
}

/// The fast-forwarding engine stays deterministic per seed on traces with
/// long idle gaps, and conserves every tweet.
#[test]
fn prop_fast_forward_engine_deterministic_per_seed() {
    use sla_autoscale::autoscale::ThresholdScaler;
    use sla_autoscale::config::SimConfig;
    use sla_autoscale::delay::DelayModel;
    use sla_autoscale::sim::Simulator;
    for_all(10, 0xFA57, |rng, case| {
        // random sparse trace: a few bursts separated by dead air
        let mut tweets = Vec::new();
        let mut id = 0u64;
        let mut t = 0.0f64;
        for _ in 0..rng.range(2, 6) {
            t += rng.next_f64() * 2_000.0 + 120.0; // gap
            for _ in 0..rng.range(5, 60) {
                t += rng.next_f64() * 0.4;
                let class = TweetClass::ALL[rng.below(3) as usize];
                tweets.push(Tweet {
                    id,
                    post_time: t,
                    class,
                    sentiment: if class == TweetClass::Analyzed { 0.5 } else { f32::NAN },
                });
                id += 1;
            }
        }
        let trace = Trace::new(tweets);
        let cfg = SimConfig { seed: 1000 + case, ..Default::default() };
        let model = DelayModel::default();
        let run =
            || Simulator::new(&cfg, &model).run(&trace, Box::new(ThresholdScaler::new(0.6)));
        let (a, b) = (run(), run());
        assert_eq!(a.history.completed(), trace.len() as u64, "case {case}");
        assert_eq!(a.history.violations(), b.history.violations(), "case {case}");
        assert_eq!(a.steps, b.steps, "case {case}");
        assert_eq!(a.cpu_hours.to_bits(), b.cpu_hours.to_bits(), "case {case}");
        assert_eq!(a.decisions, b.decisions, "case {case}");
    });
}

#[test]
fn prop_cluster_accounting() {
    for_all(200, 0xC105, |rng, case| {
        let mut cluster = Cluster::new(rng.range(1, 5) as u32, rng.next_f64() * 120.0);
        let mut expected_cpu_seconds = 0.0;
        let mut now = 0.0;
        for _ in 0..rng.range(10, 200) {
            match rng.below(4) {
                0 => cluster.scale_out(now, rng.range(0, 5) as u32),
                1 => cluster.scale_in(rng.range(0, 3) as u32),
                _ => {}
            }
            expected_cpu_seconds += cluster.active() as f64;
            now += 1.0;
            cluster.tick(now, 1.0);
            // invariant: at least one CPU always
            assert!(cluster.active() >= 1, "case {case}");
        }
        assert!(
            (cluster.cpu_hours() * 3600.0 - expected_cpu_seconds).abs() < 1e-6,
            "case {case}: accounting drift"
        );
    });
}

#[test]
fn prop_input_queue_conserves_and_orders() {
    for_all(200, 0x1F1F0, |rng, case| {
        let rate = if rng.chance(0.3) { f64::INFINITY } else { rng.next_f64() * 20.0 + 0.1 };
        let mut q = InputQueue::new(rate);
        let mut pushed = 0u64;
        let mut popped: Vec<u64> = Vec::new();
        for _ in 0..rng.range(5, 60) {
            let n = rng.range(0, 30);
            for _ in 0..n {
                q.push(pushed);
                pushed += 1;
            }
            popped.extend(q.drain_step(1.0));
        }
        // drain the rest
        for _ in 0..10_000 {
            let got = q.drain_step(1.0);
            if got.is_empty() && q.is_empty() {
                break;
            }
            popped.extend(got);
        }
        assert_eq!(popped.len() as u64, pushed, "case {case}: lost tweets");
        assert!(popped.windows(2).all(|w| w[0] < w[1]), "case {case}: FIFO broken");
    });
}

#[test]
fn prop_weibull_quantile_monotone_and_inverts_cdf() {
    for_all(200, 0x3E1B, |rng, case| {
        let w = Weibull::new(rng.next_f64() * 3.0 + 0.2, rng.next_f64() * 100.0 + 0.1);
        let mut last = 0.0;
        for i in 1..40 {
            let q = i as f64 / 40.0;
            let x = w.quantile(q);
            assert!(x >= last, "case {case}: quantile not monotone");
            assert!((w.cdf(x) - q).abs() < 1e-9, "case {case}: cdf∘quantile ≠ id");
            last = x;
        }
    });
}

#[test]
fn prop_empirical_quantile_bounds() {
    for_all(200, 0x0E57, |rng, case| {
        let n = rng.range(1, 200) as usize;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal() * 10.0).collect();
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        for i in 0..=10 {
            let q = i as f64 / 10.0;
            let v = quantile(&xs, q);
            assert!(v >= sorted[0] - 1e-12 && v <= sorted[n - 1] + 1e-12, "case {case}");
            assert!((v - quantile_sorted(&sorted, q)).abs() < 1e-12, "case {case}");
        }
    });
}

#[test]
fn prop_ema_bounded_by_input_range() {
    for_all(200, 0x00EA, |rng, case| {
        let n = rng.range(1, 300) as usize;
        let xs: Vec<f64> = (0..n).map(|_| rng.next_f64() * 100.0 - 50.0).collect();
        let alpha = rng.next_f64() * 0.99 + 0.01;
        let out = ema_series(&xs, alpha);
        let lo = xs.iter().cloned().fold(f64::MAX, f64::min);
        let hi = xs.iter().cloned().fold(f64::MIN, f64::max);
        assert!(
            out.iter().all(|&v| v >= lo - 1e-9 && v <= hi + 1e-9),
            "case {case}: EMA escaped input range"
        );
    });
}

#[test]
fn prop_trace_csv_roundtrip() {
    let dir = sla_autoscale::util::TempDir::new().unwrap();
    for_all(25, 0xC5F, |rng, case| {
        let n = rng.range(0, 300) as usize;
        let tweets: Vec<Tweet> = (0..n)
            .map(|i| {
                let class = TweetClass::ALL[rng.below(3) as usize];
                Tweet {
                    id: i as u64,
                    post_time: rng.next_f64() * 10_000.0,
                    class,
                    sentiment: if class == TweetClass::Analyzed {
                        rng.next_f64() as f32
                    } else {
                        f32::NAN
                    },
                }
            })
            .collect();
        let trace = Trace::new(tweets);
        let path = dir.join(&format!("t{case}.csv"));
        trace.write_csv(&path).unwrap();
        let back = Trace::read_csv(&path).unwrap();
        assert_eq!(back.len(), trace.len(), "case {case}");
        for (a, b) in trace.iter().zip(back.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.class, b.class);
            assert!((a.post_time - b.post_time).abs() < 2e-3, "case {case}");
        }
    });
}

#[test]
fn prop_flatmeta_roundtrip() {
    for_all(100, 0xF1A7, |rng, case| {
        let mut m = FlatMeta::default();
        let n = rng.range(0, 40);
        let mut keys = Vec::new();
        for i in 0..n {
            let key = format!("k{i}.{}", rng.below(10));
            let val = format!("v {} = {}", rng.next_u64(), rng.next_f64());
            m.insert(&key, &val);
            keys.push((key, val));
        }
        let back = FlatMeta::parse(&m.render()).unwrap();
        for (k, v) in keys {
            assert_eq!(back.get(&k).unwrap(), v, "case {case}");
        }
    });
}

#[test]
fn prop_depas_votes_respect_band_floor_and_expectation() {
    use sla_autoscale::autoscale::{AutoScaler, Decision, DepasScaler, Observation};
    use sla_autoscale::sim::history::SentimentWindows;
    for_all(200, 0xDE9A, |rng, case| {
        // random but valid fleet parameters
        let target = 0.3 + rng.next_f64() * 0.5; // (0.3, 0.8)
        let band = 0.02 + rng.next_f64() * 0.8 * (target.min(1.0 - target) - 0.02);
        let gamma = 0.1 + rng.next_f64() * 0.9;
        let n = rng.range(1, 64) as u32;
        let nodes: Vec<u64> = (0..u64::from(n)).map(|_| rng.next_u64() >> 16).collect();
        let usage = rng.next_f64();
        let w = SentimentWindows::new();
        let mut s = DepasScaler::new(target, band, gamma);
        let obs = Observation {
            now: rng.range(1, 500) as f64 * 60.0,
            cpus: n,
            pending_cpus: 0,
            in_system: 0,
            cpu_usage: usage,
            sentiment: &w,
            nodes: &nodes,
            cpu_hz: 2.0e9,
            sla_secs: 300.0,
        };
        let d = s.decide(&obs);
        assert_eq!(d, s.decide(&obs), "case {case}: decisions must be pure");
        match d {
            // jitter is bounded by band/2, so inside the half-band the
            // fleet must hold — and a vote can never exceed one per node
            Decision::Hold => {}
            Decision::ScaleOut(k) => {
                assert!(k <= n, "case {case}: {k} spawns from {n} nodes");
                assert!(
                    usage > target + band / 2.0,
                    "case {case}: spawned at usage {usage} target {target} band {band}"
                );
            }
            Decision::ScaleIn(k) => {
                assert!(n > 1 && k <= n - 1, "case {case}: {k} terminations from {n}");
                assert!(
                    usage < target - band / 2.0,
                    "case {case}: terminated at usage {usage} target {target} band {band}"
                );
            }
        }
    });
}

#[test]
fn prop_pid_actuation_and_integral_respect_the_clamp() {
    use sla_autoscale::autoscale::{AutoScaler, Decision, Observation, PidScaler};
    use sla_autoscale::delay::DelayModel;
    use sla_autoscale::sim::history::SentimentWindows;
    for_all(150, 0x91D0, |rng, case| {
        // random gains across the whole legal range
        let kp = 0.1 + rng.next_f64() * 8.0;
        let ki = rng.next_f64() * 2.0;
        let kd = rng.next_f64() * 4.0;
        let mut s =
            PidScaler::new(DelayModel::default(), 0.99999, [0.3, 0.3, 0.4], kp, ki, kd);
        let w = SentimentWindows::new();
        let mut cpus = 1u32;
        let mut now = 0.0;
        for _ in 0..rng.range(20, 120) {
            now += rng.next_f64() * 120.0 + 1.0;
            // adversarial load: dead air, modest queues, saturating floods
            let in_system = match rng.below(4) {
                0 => 0,
                1 => rng.range(0, 1_000) as usize,
                2 => 10_000_000,
                _ => 1_000_000_000,
            };
            let obs = Observation {
                now,
                cpus,
                pending_cpus: rng.range(0, 3) as u32,
                in_system,
                cpu_usage: rng.next_f64(),
                sentiment: &w,
                nodes: &[],
                cpu_hz: 2.0e9,
                sla_secs: 300.0,
            };
            match s.decide(&obs) {
                Decision::Hold => {}
                Decision::ScaleOut(n) => {
                    assert!(
                        f64::from(n) <= PidScaler::MAX_STEP,
                        "case {case}: spawn {n} breaks the actuation clamp"
                    );
                    cpus += n;
                }
                Decision::ScaleIn(n) => {
                    assert!(
                        f64::from(n) <= PidScaler::MAX_STEP,
                        "case {case}: kill {n} breaks the actuation clamp"
                    );
                    assert!(n <= cpus - 1, "case {case}: scale-in below one CPU");
                    cpus -= n;
                }
            }
            assert!(
                s.integral_term().abs() <= PidScaler::MAX_STEP + 1e-12,
                "case {case}: integrator wound up past the clamp"
            );
        }
    });
}

#[test]
fn prop_queueing_target_monotone_in_load_and_backlog() {
    use sla_autoscale::autoscale::{Observation, QueueingScaler};
    use sla_autoscale::delay::DelayModel;
    use sla_autoscale::sim::history::SentimentWindows;
    for_all(300, 0x0DE0, |rng, case| {
        let rho = 0.05 + rng.next_f64() * 0.9;
        let w_frac = 0.05 + rng.next_f64() * 0.95;
        let s =
            QueueingScaler::new(DelayModel::default(), 0.99999, [0.3, 0.3, 0.4], rho, w_frac);
        let w = SentimentWindows::new();
        let cpus = rng.range(1, 64) as u32;
        let obs = |usage: f64, in_system: usize| Observation {
            now: 60.0,
            cpus,
            pending_cpus: 0,
            in_system,
            cpu_usage: usage,
            sentiment: &w,
            nodes: &[],
            cpu_hz: 2.0e9,
            sla_secs: 300.0,
        };
        // monotone in the offered-load (arrival-rate) estimate at fixed backlog
        let n = rng.range(0, 2_000_000) as usize;
        let (u1, u2) = (rng.next_f64(), rng.next_f64());
        let (lo, hi) = if u1 <= u2 { (u1, u2) } else { (u2, u1) };
        assert!(
            s.target_cpus(&obs(lo, n)) <= s.target_cpus(&obs(hi, n)),
            "case {case}: target shrank as offered load grew (rho={rho} w={w_frac})"
        );
        // monotone in the in-system count at fixed offered load
        let u = rng.next_f64();
        let (a, b) = (rng.range(0, 2_000_000) as usize, rng.range(0, 2_000_000) as usize);
        let (na, nb) = if a <= b { (a, b) } else { (b, a) };
        assert!(
            s.target_cpus(&obs(u, na)) <= s.target_cpus(&obs(u, nb)),
            "case {case}: target shrank as the backlog grew (rho={rho} w={w_frac})"
        );
        assert!(s.target_cpus(&obs(0.0, 0)) >= 1, "case {case}: target below one CPU");
    });
}

#[test]
fn prop_hybrid_switches_at_most_once_on_a_constant_trace() {
    use sla_autoscale::autoscale::{AutoScaler, HybridScaler, Observation};
    use sla_autoscale::delay::DelayModel;
    use sla_autoscale::sim::history::SentimentWindows;
    for_all(100, 0x8B1D, |rng, case| {
        let upper = 0.2 + rng.next_f64() * 0.8;
        let horizon = 30.0 + rng.next_f64() * 270.0;
        let mut s =
            HybridScaler::new(DelayModel::default(), 0.99999, [0.3, 0.3, 0.4], upper, horizon);
        let in_system = rng.range(0, 100_000) as usize;
        let usage = rng.next_f64();
        let w = SentimentWindows::new();
        for t in 0..60 {
            s.decide(&Observation {
                now: t as f64 * 60.0,
                cpus: 4,
                pending_cpus: 0,
                in_system,
                cpu_usage: usage,
                sentiment: &w,
                nodes: &[],
                cpu_hz: 2.0e9,
                sla_secs: 300.0,
            });
        }
        assert!(
            s.switches() <= 1,
            "case {case}: mode oscillated on a constant trace (upper={upper} h={horizon})"
        );
        // constant traces are perfectly forecastable, so trust is earned
        assert!(s.proactive_active(), "case {case}: exact forecasts never earned trust");
        assert!(s.prediction_error() < HybridScaler::TRUST, "case {case}");
    });
}

/// The injected failure/boot schedule is a pure function of
/// `(failure_seed, VM request index)`: the serial engine, the lockstep
/// batch kernel and the folded replication waves all see the same fault
/// history, bit for bit.
#[test]
fn prop_failure_injection_pure_across_serial_batch_and_waves() {
    use sla_autoscale::autoscale::ScalerSpec;
    use sla_autoscale::config::SimConfig;
    use sla_autoscale::delay::DelayModel;
    use sla_autoscale::scenario::run_replications;
    use sla_autoscale::sim::{run_batch, SimScratch, Simulator};
    for_all(6, 0xFA11, |rng, case| {
        // random bursty trace, small enough to simulate many times
        let mut tweets = Vec::new();
        let mut id = 0u64;
        let mut t = 0.0f64;
        for _ in 0..rng.range(2, 4) {
            t += rng.next_f64() * 900.0 + 60.0;
            for _ in 0..rng.range(40, 160) {
                t += rng.next_f64() * 0.2;
                let class = TweetClass::ALL[rng.below(3) as usize];
                tweets.push(Tweet {
                    id,
                    post_time: t,
                    class,
                    sentiment: if class == TweetClass::Analyzed { 0.5 } else { f32::NAN },
                });
                id += 1;
            }
        }
        let trace = Trace::new(tweets);
        let cfg = SimConfig {
            seed: 2_000 + case,
            failure_mtbf_secs: Some(300.0 + rng.next_f64() * 3_000.0),
            boot_jitter_secs: Some(rng.next_f64() * 60.0 + 1.0),
            failure_seed: rng.next_u64(),
            ..Default::default()
        };
        let model = DelayModel::default();
        let spec = ScalerSpec::threshold(70.0);
        let mix = [0.3, 0.3, 0.4];
        // batch-kernel lanes against the serial engine, per seed
        let seeds: Vec<u64> =
            (0..4u64).map(|i| cfg.seed.wrapping_add(i.wrapping_mul(7919))).collect();
        let scalers: Vec<_> = seeds.iter().map(|_| spec.build(&model, mix)).collect();
        let mut scratch = SimScratch::new();
        let lanes = run_batch(&trace, &cfg, &model, scalers, &seeds, &mut scratch);
        for (lane, &seed) in lanes.iter().zip(&seeds) {
            let want =
                Simulator::new(&cfg.with_seed(seed), &model).run(&trace, spec.build(&model, mix));
            let tag = format!("case {case} seed {seed}");
            assert_eq!(lane.violation_pct.to_bits(), want.violation_pct().to_bits(), "{tag}");
            assert_eq!(lane.cpu_hours.to_bits(), want.cpu_hours.to_bits(), "{tag}");
            assert_eq!(lane.p99_delay.to_bits(), want.history.p99_delay().to_bits(), "{tag}");
            assert_eq!(lane.decisions, want.decisions, "{tag}");
        }
        // wide waves fold to the one-lane wave bit for bit
        let one = run_replications(&trace, &cfg, &model, &spec, mix, "p".into(), 4, 1);
        let wide = run_replications(&trace, &cfg, &model, &spec, mix, "p".into(), 4, 4);
        assert_eq!(one.reps, wide.reps, "case {case}");
        assert_eq!(one.violation_pct.to_bits(), wide.violation_pct.to_bits(), "case {case}");
        assert_eq!(one.p99_delay.to_bits(), wide.p99_delay.to_bits(), "case {case}");
        assert_eq!(one.sla_score.to_bits(), wide.sla_score.to_bits(), "case {case}");
        assert_eq!(one.cpu_hours.to_bits(), wide.cpu_hours.to_bits(), "case {case}");
    });
}

#[test]
fn prop_p99_histogram_order_independent_and_bounded() {
    use sla_autoscale::sim::history::{Completed, History};
    for_all(150, 0x99DE, |rng, case| {
        let sla = rng.next_f64() * 400.0 + 10.0;
        let n = rng.range(1, 400) as usize;
        let delays: Vec<f64> = (0..n)
            .map(|_| {
                if rng.chance(0.1) {
                    // overflow tail: past the histogram's 16-SLA span
                    sla * (16.0 + rng.next_f64() * 50.0)
                } else {
                    rng.next_f64() * sla * 4.0
                }
            })
            .collect();
        let record_all = |ds: &[f64]| {
            let mut h = History::new(sla);
            for &d in ds {
                h.record(
                    Completed {
                        post_time: 0.0,
                        finished_at: d,
                        class: TweetClass::Discarded,
                        sentiment: f32::NAN,
                    },
                    0.0,
                );
            }
            h
        };
        let fwd = record_all(&delays);
        let mut rev = delays.clone();
        rev.reverse();
        let bwd = record_all(&rev);
        assert_eq!(
            fwd.p99_delay().to_bits(),
            bwd.p99_delay().to_bits(),
            "case {case}: p99 must not depend on completion order"
        );
        let p99 = fwd.p99_delay();
        let mut sorted = delays.clone();
        sorted.sort_by(f64::total_cmp);
        let target = ((0.99 * n as f64).ceil() as usize).max(1);
        let exact = sorted[target - 1];
        assert!(p99 <= fwd.max_delay() + 1e-9, "case {case}: p99 {p99} above the maximum");
        assert!(
            p99 >= exact - 1e-9,
            "case {case}: estimate {p99} below the exact sample quantile {exact}"
        );
    });
}

#[test]
fn prop_batcher_covers_any_n() {
    use sla_autoscale::runtime::plan;
    for_all(300, 0xBA7C, |rng, case| {
        // random ascending variant sets
        let mut variants: Vec<usize> =
            (0..rng.range(1, 4)).map(|_| 1 << rng.range(0, 9)).collect();
        variants.sort_unstable();
        variants.dedup();
        let n = rng.range(0, 2000) as usize;
        let p = plan(n, &variants);
        let covered: usize = p.iter().map(|l| l.fill).sum();
        assert_eq!(covered, n, "case {case}: variants={variants:?}");
        for l in &p {
            assert!(variants.contains(&l.batch), "case {case}");
            assert!(l.fill >= 1 && l.fill <= l.batch, "case {case}");
        }
    });
}
