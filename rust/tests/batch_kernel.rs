//! Integration tests for the lockstep replication-batch kernel
//! (`sim::run_batch`): the bit-identity property against the serial
//! engine across scaler families, seeds and queue regimes, the
//! degenerate one-lane wave, and the CPU-hours denominator contract of
//! the scenario runner built on top of it.

use sla_autoscale::autoscale::ScalerSpec;
use sla_autoscale::config::SimConfig;
use sla_autoscale::delay::DelayModel;
use sla_autoscale::scenario::{run_replications, TraceSource};
use sla_autoscale::sim::{run_batch, LaneResult, SimResult, SimScratch, Simulator};
use sla_autoscale::workload::MatchSpec;

fn source(total: u64) -> TraceSource {
    TraceSource::spec(
        MatchSpec {
            opponent: "BatchIT",
            date: "—",
            total_tweets: total,
            length_hours: 0.25,
            events: vec![],
        },
        false,
    )
}

fn mix() -> [f64; 3] {
    [0.30, 0.30, 0.40]
}

/// The scenario runner's lane-seed schedule.
fn lane_seeds(base: u64, r: usize) -> Vec<u64> {
    (0..r as u64).map(|i| base.wrapping_add(i.wrapping_mul(7919))).collect()
}

fn assert_lane_matches(lane: &LaneResult, want: &SimResult, tag: &str) {
    assert_eq!(lane.violation_pct.to_bits(), want.violation_pct().to_bits(), "{tag}");
    assert_eq!(lane.cpu_hours.to_bits(), want.cpu_hours.to_bits(), "{tag}");
    assert_eq!(lane.p99_delay.to_bits(), want.history.p99_delay().to_bits(), "{tag}");
    assert_eq!(lane.completed, want.history.completed(), "{tag}");
    assert_eq!(lane.violations, want.history.violations(), "{tag}");
    assert_eq!(lane.decisions, want.decisions, "{tag}");
}

/// Lockstep property: every lane of a batched wave is
/// `f64::to_bits`-identical to the serial engine run of the same seed —
/// across scaler families, on both the unlimited and the rate-limited
/// queue path, down to the scaling-decision trajectory.
#[test]
fn batched_lanes_bit_identical_to_serial() {
    let trace = source(30_000).load().unwrap();
    let model = DelayModel::default();
    let configs = [
        SimConfig { sla_secs: 60.0, ..Default::default() },
        SimConfig { input_rate: Some(50.0), adapt_secs: 30.0, ..Default::default() },
    ];
    let specs = [
        ScalerSpec::threshold(70.0),
        ScalerSpec::load(0.99),
        ScalerSpec::load_plus_appdata(0.99999, 2),
        ScalerSpec::predictive(120.0),
        ScalerSpec::Vertical,
        ScalerSpec::depas(0.7, 0.1, 0.5),
        ScalerSpec::queueing(0.7, 0.5),
        ScalerSpec::pid(2.0, 0.5, 0.25),
        ScalerSpec::hybrid(80.0, 120.0),
    ];
    let mut scratch = SimScratch::new();
    for cfg in &configs {
        for spec in &specs {
            let seeds = lane_seeds(cfg.seed, 5);
            let scalers: Vec<_> = seeds.iter().map(|_| spec.build(&model, mix())).collect();
            let lanes = run_batch(&trace, cfg, &model, scalers, &seeds, &mut scratch);
            assert_eq!(lanes.len(), seeds.len());
            for (lane, &seed) in lanes.iter().zip(&seeds) {
                let scfg = cfg.with_seed(seed);
                let want = Simulator::new(&scfg, &model).run(&trace, spec.build(&model, mix()));
                let tag = format!("{spec} rate={:?} seed={seed}", cfg.input_rate);
                assert_lane_matches(lane, &want, &tag);
            }
        }
    }
}

/// The fault axes go through the batch kernel unchanged: with failure
/// injection and boot-time jitter armed, every lane still reproduces the
/// serial engine of the same seed bit for bit — the fault schedule
/// depends on the VM request index, never on which kernel requests it.
#[test]
fn fault_injected_lanes_bit_identical_to_serial() {
    let trace = source(20_000).load().unwrap();
    let model = DelayModel::default();
    let configs = [
        SimConfig { failure_mtbf_secs: Some(600.0), ..Default::default() },
        SimConfig { boot_jitter_secs: Some(45.0), ..Default::default() },
        SimConfig {
            failure_mtbf_secs: Some(900.0),
            boot_jitter_secs: Some(30.0),
            failure_seed: 11,
            sla_secs: 60.0,
            ..Default::default()
        },
    ];
    let specs =
        [ScalerSpec::threshold(70.0), ScalerSpec::queueing(0.7, 0.5), ScalerSpec::hybrid(80.0, 120.0)];
    let mut scratch = SimScratch::new();
    for cfg in &configs {
        for spec in &specs {
            let seeds = lane_seeds(cfg.seed, 4);
            let scalers: Vec<_> = seeds.iter().map(|_| spec.build(&model, mix())).collect();
            let lanes = run_batch(&trace, cfg, &model, scalers, &seeds, &mut scratch);
            for (lane, &seed) in lanes.iter().zip(&seeds) {
                let scfg = cfg.with_seed(seed);
                let want = Simulator::new(&scfg, &model).run(&trace, spec.build(&model, mix()));
                let tag = format!(
                    "{spec} mtbf={:?} jitter={:?} seed={seed}",
                    cfg.failure_mtbf_secs, cfg.boot_jitter_secs
                );
                assert_lane_matches(lane, &want, &tag);
            }
        }
    }
}

/// Bounded idle fast-forward under armed fault axes: a sparse trace
/// (2 000 tweets over 2 h leaves long idle stretches) with node deaths
/// and jittered boots pending. The fast-forward must stop at
/// `min(next arrival, next cluster event)` so every death and delayed
/// boot is processed at the same step as under dense stepping — on both
/// the serial engine (dense reference forced via a huge `input_rate`,
/// which disables the fast-forward gate) and the batch kernel. This is
/// also the path where the SIMD lane sweeps meet retired/heterogeneous
/// lanes; a `--no-default-features` run of this same test pins the
/// scalar fallback to the identical bits.
#[test]
fn sparse_fault_fast_forward_bit_identical() {
    let trace = TraceSource::spec(
        MatchSpec {
            opponent: "SparseIT",
            date: "—",
            total_tweets: 2_000,
            length_hours: 2.0,
            events: vec![],
        },
        false,
    )
    .load()
    .unwrap();
    let model = DelayModel::default();
    let configs = [
        SimConfig { failure_mtbf_secs: Some(1_800.0), ..Default::default() },
        SimConfig { boot_jitter_secs: Some(25.0), ..Default::default() },
        SimConfig {
            failure_mtbf_secs: Some(1_200.0),
            boot_jitter_secs: Some(15.0),
            failure_seed: 5,
            ..Default::default()
        },
    ];
    let specs = [ScalerSpec::threshold(60.0), ScalerSpec::load(0.99)];
    let mut scratch = SimScratch::new();
    for cfg in &configs {
        // Dense reference: an input rate far above the offered load
        // admits every tweet immediately but disables the idle
        // fast-forward on both paths.
        let dense_cfg = SimConfig { input_rate: Some(1e15), ..cfg.clone() };
        for spec in &specs {
            let seeds = lane_seeds(cfg.seed, 3);
            let scalers: Vec<_> = seeds.iter().map(|_| spec.build(&model, mix())).collect();
            let lanes = run_batch(&trace, cfg, &model, scalers, &seeds, &mut scratch);
            for (lane, &seed) in lanes.iter().zip(&seeds) {
                let tag = format!(
                    "{spec} mtbf={:?} jitter={:?} seed={seed}",
                    cfg.failure_mtbf_secs, cfg.boot_jitter_secs
                );
                // fast-forwarding serial engine
                let scfg = cfg.with_seed(seed);
                let want = Simulator::new(&scfg, &model).run(&trace, spec.build(&model, mix()));
                assert_lane_matches(lane, &want, &tag);
                // dense-stepping serial engine
                let dcfg = dense_cfg.with_seed(seed);
                let dense = Simulator::new(&dcfg, &model).run(&trace, spec.build(&model, mix()));
                assert_eq!(
                    want.violation_pct().to_bits(),
                    dense.violation_pct().to_bits(),
                    "dense {tag}"
                );
                assert_eq!(want.cpu_hours.to_bits(), dense.cpu_hours.to_bits(), "dense {tag}");
                assert_eq!(want.history.completed(), dense.history.completed(), "dense {tag}");
                assert_eq!(want.decisions, dense.decisions, "dense {tag}");
            }
        }
    }
}

/// Degenerate wave: R = 1 goes through the batch kernel unchanged.
#[test]
fn single_lane_wave_matches_serial() {
    let trace = source(12_000).load().unwrap();
    let cfg = SimConfig::default();
    let model = DelayModel::default();
    let spec = ScalerSpec::load(0.99999);
    let mut scratch = SimScratch::new();
    let scalers = vec![spec.build(&model, mix())];
    let lanes = run_batch(&trace, &cfg, &model, scalers, &[cfg.seed], &mut scratch);
    assert_eq!(lanes.len(), 1);
    let want = Simulator::new(&cfg, &model).run(&trace, spec.build(&model, mix()));
    assert_lane_matches(&lanes[0], &want, "R=1");
}

/// Wave overshoot keeps the CI stopping rule's fold: a wide wave that
/// overshoots the stopping point discards the excess lanes, so both the
/// violation fold and the CPU-hours mean see exactly the serial rep
/// set — bit-identical results, same rep count.
#[test]
fn overshoot_waves_fold_like_serial() {
    let trace = source(25_000).load().unwrap();
    let model = DelayModel::default();
    let cfg = SimConfig { sla_secs: 45.0, ..Default::default() };
    let spec = ScalerSpec::threshold(75.0);
    let serial = run_replications(&trace, &cfg, &model, &spec, mix(), spec.to_string(), 5, 1);
    for wave in [3, 4, 8] {
        let wide = run_replications(
            &trace, &cfg, &model, &spec, mix(), spec.to_string(), 5, wave,
        );
        assert_eq!(serial.reps, wide.reps, "wave={wave}");
        assert_eq!(serial.violation_pct.to_bits(), wide.violation_pct.to_bits(), "wave={wave}");
        assert_eq!(serial.cpu_hours.to_bits(), wide.cpu_hours.to_bits(), "wave={wave}");
    }
}

/// `ScenarioResult::cpu_hours` averages over exactly the folded reps —
/// discarded overshoot lanes feed neither the numerator nor the
/// denominator. Recomputed from the kernel's own per-lane results, the
/// mean must match bit for bit.
#[test]
fn cpu_hours_denominator_counts_only_folded_reps() {
    let trace = source(18_000).load().unwrap();
    let model = DelayModel::default();
    let cfg = SimConfig::default();
    let spec = ScalerSpec::load(0.99);
    let r = run_replications(&trace, &cfg, &model, &spec, mix(), spec.to_string(), 4, 3);
    let seeds = lane_seeds(cfg.seed, r.reps);
    let scalers: Vec<_> = seeds.iter().map(|_| spec.build(&model, mix())).collect();
    let mut scratch = SimScratch::new();
    let lanes = run_batch(&trace, &cfg, &model, scalers, &seeds, &mut scratch);
    let mean = lanes.iter().map(|l| l.cpu_hours).sum::<f64>() / r.reps as f64;
    assert_eq!(r.cpu_hours.to_bits(), mean.to_bits(), "{} vs {mean}", r.cpu_hours);
}
