//! DET-004 passing fixture: the work stays on the calling thread; only
//! scenario/runner.rs and scenario/steal.rs may schedule.

pub fn fan_out(jobs: &[u64]) -> u64 {
    let mut acc = 0u64;
    for j in jobs {
        acc = acc.wrapping_add(*j);
    }
    acc
}
