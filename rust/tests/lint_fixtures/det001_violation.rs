//! DET-001 violating fixture: a wall-clock read outside the allowlist.
//! Plain data for `lint_engine.rs` — never compiled (test targets are
//! explicit in Cargo.toml).

pub fn stamp_secs() -> f64 {
    let started = std::time::Instant::now();
    busy_work();
    started.elapsed().as_secs_f64()
}

fn busy_work() {}
