//! DET-003 passing fixture: randomness derived from a scenario-keyed
//! seed through the crate's own generator.

pub fn jitter(seed: u64) -> u64 {
    let mut r = crate::rng::Rng::new(seed);
    r.below(1000)
}
