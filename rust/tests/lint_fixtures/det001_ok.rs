//! DET-001 passing fixture: time flows in as data (the simulated clock),
//! never from the host. Mentioning Instant::now in a comment or "string"
//! must not trip the lexical pass either.

pub fn stamp_secs(sim_clock: f64, step: f64) -> f64 {
    let label = "not a real Instant::now read";
    let _ = label;
    sim_clock + step
}
