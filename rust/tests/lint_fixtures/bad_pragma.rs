//! Pragma-hygiene fixture: each malformed suppression below must become
//! a DET-000 finding (and must not suppress the violation it precedes).

// det:allow(DET-001)
pub fn missing_reason() -> std::time::Instant {
    std::time::Instant::now()
}

// det:allow(DET-999, reason = "no such rule")
pub fn unknown_rule() {}
