//! DET-002 passing fixture: ordered container, deterministic iteration.
//! Hash lookups (`get`/`contains`) stay fine — only iteration order is
//! the hazard.

use std::collections::{BTreeMap, HashMap};

pub fn table(rows: &BTreeMap<u64, f64>) -> Vec<(u64, f64)> {
    let mut out = Vec::new();
    for (k, v) in rows.iter() {
        out.push((*k, *v));
    }
    out
}

pub fn lookup(cache: &HashMap<u64, f64>, key: u64) -> Option<f64> {
    cache.get(&key).copied()
}
