//! DET-005 violating fixture: float accumulation over an unordered
//! iterator in a result path. Also trips DET-002 (the iteration itself).

use std::collections::HashMap;

pub fn total_violation_pct(per_scenario: &HashMap<u64, f64>) -> f64 {
    per_scenario.values().sum::<f64>()
}
