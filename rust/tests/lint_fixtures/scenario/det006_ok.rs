//! DET-006 passing fixture: the serializer pins its format version next
//! to the magic, so readers can reject foreign layouts.

pub const MAGIC: [u8; 8] = *b"FIXTURE\0";
pub const FORMAT_VERSION: u32 = 1;

pub fn header(n: u64) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    out.extend_from_slice(&n.to_le_bytes());
    out
}
