//! DET-005 passing fixture: accumulate over an ordered container so the
//! non-associative float sum is a function of the data, not the process.

use std::collections::BTreeMap;

pub fn total_violation_pct(per_scenario: &BTreeMap<u64, f64>) -> f64 {
    per_scenario.values().sum::<f64>()
}
