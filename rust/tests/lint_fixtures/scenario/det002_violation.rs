//! DET-002 violating fixture: hash-order iteration in a result-bearing
//! module (this file lives under a `scenario/` path component).

use std::collections::HashMap;

pub fn table(rows: &HashMap<u64, f64>) -> Vec<(u64, f64)> {
    let mut out = Vec::new();
    for (k, v) in rows.iter() {
        out.push((*k, *v));
    }
    out
}
