//! DET-006 violating fixture: a record layout with magic bytes but no
//! pinned format version in the file that serializes it.

pub const MAGIC: [u8; 8] = *b"FIXTURE\0";

pub fn header(n: u64) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&n.to_le_bytes());
    out
}
