//! Suppression fixture: both pragma placements — trailing the violating
//! line, and on the line above it — must suppress, and both reasons must
//! surface in the report's `allowed` list.

pub fn stamp_trailing() -> std::time::Instant {
    std::time::Instant::now() // det:allow(DET-001, reason = "fixture: timing is display-only")
}

pub fn stamp_above() -> std::time::Instant {
    // det:allow(DET-001, reason = "fixture: standalone pragma form")
    std::time::Instant::now()
}
