//! DET-004 violating fixture: a thread spawned outside the sanctioned
//! runners.

pub fn fan_out() -> std::thread::JoinHandle<u64> {
    std::thread::spawn(|| 42)
}
