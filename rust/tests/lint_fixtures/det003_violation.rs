//! DET-003 violating fixture: ambient randomness outside rng.rs.

pub fn jitter() -> u64 {
    let mut r = rand::thread_rng();
    r.gen()
}
