//! Integration: the PJRT-served artifacts must reproduce, bit-for-bit-ish,
//! the probabilities the Python side computed at export time — the
//! definitive check that HLO text round-trips numerics and that the Rust
//! tokenizer matches the Python vectorizer.
//!
//! Requires `make artifacts`; tests are skipped (not failed) otherwise so
//! `cargo test` stays meaningful on a fresh checkout.

use sla_autoscale::runtime::{Meta, ModelEngine};
use sla_autoscale::sentiment::{Sentiment, SentimentEngine};
use std::path::Path;

fn artifacts_dir() -> Option<&'static Path> {
    let dir = Path::new("artifacts");
    if dir.join("meta.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn meta_loads_and_validates() {
    let Some(dir) = artifacts_dir() else { return };
    let meta = Meta::load(dir).expect("meta loads");
    assert_eq!(meta.vocab, 1024);
    assert_eq!(meta.classes, 3);
    assert_eq!(meta.labels, vec!["positive", "negative", "neutral"]);
    assert!(meta.batch_variants.contains(&64));
    assert!(meta.train_acc > 0.9);
}

#[test]
fn golden_probs_reproduced_through_pjrt() {
    let Some(dir) = artifacts_dir() else { return };
    let meta = Meta::load(dir).unwrap();
    let mut engine = ModelEngine::load(dir).expect("engine loads");
    let scores = engine.score_batch(&meta.golden.texts).expect("scores");
    assert_eq!(scores.len(), meta.golden.texts.len());
    for (i, (got, want)) in scores.iter().zip(&meta.golden.probs).enumerate() {
        let g = [got.p_pos, got.p_neg, got.p_neu];
        for (a, b) in g.iter().zip(want) {
            assert!(
                (a - b).abs() < 1e-4,
                "golden {i}: rust {g:?} vs python {want:?}"
            );
        }
    }
}

#[test]
fn golden_scores_and_labels_consistent() {
    let Some(dir) = artifacts_dir() else { return };
    let meta = Meta::load(dir).unwrap();
    let mut engine = ModelEngine::load(dir).unwrap();
    let scores = engine.score_batch(&meta.golden.texts).unwrap();
    let mut correct = 0;
    for (i, s) in scores.iter().enumerate() {
        assert!((s.score() - meta.golden.scores[i]).abs() < 1e-4);
        if s.argmax() == meta.golden.labels[i] as usize {
            correct += 1;
        }
    }
    // The classifier has >90% train accuracy; on 8 goldens allow 1 miss.
    assert!(correct >= meta.golden.texts.len() - 1, "only {correct} correct");
}

#[test]
fn probabilities_form_a_simplex() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = ModelEngine::load(dir).unwrap();
    let texts: Vec<String> = (0..100)
        .map(|i| format!("pos{} neg{} neu{} topic{} noise{}", i % 48, (i * 7) % 48, i % 96, i % 32, i))
        .collect();
    let scores = engine.score_batch(&texts).unwrap();
    assert_eq!(scores.len(), 100);
    for s in &scores {
        let sum = s.p_pos + s.p_neg + s.p_neu;
        assert!((sum - 1.0).abs() < 1e-4, "not a simplex: {s:?}");
        assert!(s.p_pos >= 0.0 && s.p_neg >= 0.0 && s.p_neu >= 0.0);
    }
}

#[test]
fn batch_plan_sizes_are_transparent() {
    // Scoring n tweets must give n results for awkward n (crosses variant
    // boundaries: 1, 7, 8, 9, 63, 64, 65, 255, 256, 257, 300).
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = ModelEngine::load(dir).unwrap();
    for n in [1usize, 7, 8, 9, 63, 64, 65, 255, 256, 257, 300] {
        let texts: Vec<String> = (0..n).map(|_| "pos1 pos2 neu3 topic4".to_string()).collect();
        let scores = engine.score_batch(&texts).unwrap();
        assert_eq!(scores.len(), n, "n={n}");
        // identical rows → identical scores regardless of padding/variant
        let first = scores[0];
        for s in &scores {
            assert!((s.p_pos - first.p_pos).abs() < 1e-5, "padding leaked into row scores");
        }
    }
}

#[test]
fn model_engine_agrees_with_lexicon_on_polarity() {
    // The trained classifier and the rule-based lexicon must agree on the
    // dominant pole for strongly-polarized synthetic tweets.
    let Some(dir) = artifacts_dir() else { return };
    let mut model = ModelEngine::load(dir).unwrap();
    let mut lex = sla_autoscale::sentiment::LexiconEngine::new();
    let texts: Vec<String> = vec![
        "pos1 pos2 pos3 pos4 pos5 topic1".into(),
        "neg1 neg2 neg3 neg4 neg5 topic1".into(),
        "neu1 neu2 neu3 neu4 topic2 noise77".into(),
    ];
    let m: Vec<Sentiment> = model.score_batch(&texts).unwrap();
    let l: Vec<Sentiment> = lex.score_batch(&texts).unwrap();
    for (i, (a, b)) in m.iter().zip(&l).enumerate() {
        assert_eq!(a.argmax(), b.argmax(), "disagree on {i}: model {a:?} lexicon {b:?}");
    }
}
