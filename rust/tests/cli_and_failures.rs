//! CLI smoke tests (the shipped binary) and failure-injection tests
//! (corrupted artifacts, hostile configs, degenerate workloads).

use sla_autoscale::autoscale::{AutoScaler, LoadScaler, ThresholdScaler};
use sla_autoscale::config::SimConfig;
use sla_autoscale::delay::DelayModel;
use sla_autoscale::runtime::{cpu_client, Executable, Meta};
use sla_autoscale::sim::Simulator;
use sla_autoscale::util::TempDir;
use sla_autoscale::workload::{generate, GeneratorConfig, MatchSpec, Trace};
use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_sla-autoscale"))
}

#[test]
fn cli_matches_lists_table2() {
    let out = bin().arg("matches").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for m in ["England", "Spain", "4309863"] {
        assert!(text.contains(m), "missing {m} in:\n{text}");
    }
}

#[test]
fn cli_no_args_prints_usage() {
    let out = bin().output().unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("USAGE"));
}

#[test]
fn cli_unknown_opponent_fails_cleanly() {
    let out = bin().args(["sim", "Germany"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown opponent"));
}

#[test]
fn cli_unknown_experiment_lists_available() {
    let out = bin().args(["exp", "fig99"]).output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("fig7"), "should list ids: {err}");
}

#[test]
fn cli_gen_writes_csv_roundtrip() {
    let dir = TempDir::new().unwrap();
    let path = dir.join("england.csv");
    let out = bin()
        .args(["gen", "England", "--out", path.to_str().unwrap(), "--seed", "5"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let trace = Trace::read_csv(&path).unwrap();
    assert!(trace.len() > 300_000, "got {}", trace.len());
}

#[test]
fn cli_sim_fast_runs_and_reports() {
    let out = bin()
        .args(["sim", "France", "--algo", "threshold-80", "--fast"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("CPU-hours"), "{text}");
    assert!(text.contains("threshold-80%"));
}

#[test]
fn cli_bad_algo_rejected() {
    let out = bin().args(["sim", "France", "--algo", "magic-9000"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown algorithm"));
}

#[test]
fn cli_sim_accepts_composite_spec() {
    let out = bin()
        .args(["sim", "France", "--algo", "load-q99.999%+appdata+2", "--fast"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("load-q99.999%+appdata+2"));
}

#[test]
fn cli_matrix_runs_a_grid() {
    let out = bin()
        .args([
            "matrix",
            "France,England",
            "--algos",
            "threshold-80%,load-q99%",
            "--fast",
            "--threads",
            "2",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    for want in [
        "scenario matrix — 4 scenarios",
        "France/threshold-80%",
        "France/load-q99%",
        "England/threshold-80%",
        "England/load-q99%",
    ] {
        assert!(text.contains(want), "missing {want:?} in:\n{text}");
    }
}

#[test]
fn cli_matrix_adversarial_axes_label_rows_and_converge() {
    let out = bin()
        .args([
            "matrix",
            "France",
            "--algos",
            "queueing-0.7-0.5,pid-2-0.5-0.25,hybrid-80-120",
            "--fast",
            "--serial",
            "--max-reps",
            "2",
            "--mtbf",
            "1800",
            "--boot-jitter",
            "20",
            "--failure-seed",
            "11",
            "--flash-crowd",
            "4",
            "--echo-gap",
            "10",
            "--lead-min",
            "0,1.5",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    for want in [
        "scenario matrix — 6 scenarios",
        "queueing-0.7-0.5",
        "pid-2-0.5-0.25",
        "hybrid-80-120",
        "flash=4.0",
        "echo=10.0m",
        "mtbf=1800s",
        "boot=20s",
        "fseed=11",
        "p99-delay(s)",
        "SLA-score",
    ] {
        assert!(text.contains(want), "missing {want:?} in:\n{text}");
    }
}

#[test]
fn cli_matrix_streams_and_reuses_the_disk_cache() {
    let dir = TempDir::new().unwrap();
    let cache = dir.join("traces");
    let run = || {
        bin()
            .args([
                "matrix",
                "France",
                "--algos",
                "threshold-80%",
                "--fast",
                "--threads",
                "2",
                "--lead-min",
                "0,3",
                "--stream",
                "--cache-dir",
                cache.to_str().unwrap(),
            ])
            .output()
            .unwrap()
    };
    let streamed_rows = |stdout: &str| -> Vec<String> {
        let mut rows: Vec<String> = stdout
            .lines()
            .filter(|l| l.contains(',') && l.contains("threshold-80%/"))
            .map(String::from)
            .collect();
        rows.sort();
        rows
    };

    let first = run();
    assert!(first.status.success(), "{}", String::from_utf8_lossy(&first.stderr));
    let text = String::from_utf8_lossy(&first.stdout).into_owned();
    assert!(
        text.contains("scenario,violation_pct,p99_delay,cpu_hours,sla_score,reps"),
        "{text}"
    );
    let rows = streamed_rows(&text);
    assert_eq!(rows.len(), 2, "one streamed CSV line per scenario:\n{text}");
    assert!(rows.iter().any(|r| r.contains("lead=0.00m")), "{text}");
    assert!(rows.iter().any(|r| r.contains("lead=3.00m")), "{text}");
    // the final batch table still prints after the stream
    assert!(text.contains("scenario matrix — 2 scenarios"), "{text}");
    // one versioned store file per workload shape
    let stored = std::fs::read_dir(&cache)
        .unwrap()
        .filter(|e| {
            e.as_ref().unwrap().path().extension().map(|x| x == "trace").unwrap_or(false)
        })
        .count();
    assert_eq!(stored, 2, "cache dir must hold one trace per shape");

    // A second process streams identical content, fed from the disk cache.
    let second = run();
    assert!(second.status.success(), "{}", String::from_utf8_lossy(&second.stderr));
    let rows2 = streamed_rows(&String::from_utf8_lossy(&second.stdout));
    assert_eq!(rows, rows2, "cross-process runs must stream identical results");
}

#[test]
fn cli_matrix_shards_journal_and_merge_bit_identically() {
    let dir = TempDir::new().unwrap();
    let journal = dir.join("journal");
    let cache = dir.join("traces");
    let grid = |extra: &[&str]| {
        let mut c = bin();
        c.args([
            "matrix",
            "France,Japan",
            "--algos",
            "threshold-80%,load-q99%",
            "--fast",
            "--threads",
            "2",
            "--max-reps",
            "3",
            "--cache-dir",
            cache.to_str().unwrap(),
        ]);
        c.args(extra);
        c.output().unwrap()
    };
    for shard in ["0/2", "1/2"] {
        let out = grid(&["--shard", shard, "--journal", journal.to_str().unwrap()]);
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    }
    let merged = bin().args(["matrix", "merge", journal.to_str().unwrap()]).output().unwrap();
    assert!(merged.status.success(), "{}", String::from_utf8_lossy(&merged.stderr));
    let merged_text = String::from_utf8_lossy(&merged.stdout).into_owned();
    let single = grid(&["--serial"]);
    assert!(single.status.success(), "{}", String::from_utf8_lossy(&single.stderr));
    let single_text = String::from_utf8_lossy(&single.stdout).into_owned();
    // Compare the table blocks: the merged folded table must be
    // bit-identical (rendered digits included) to the one-process run.
    let table = |text: &str| -> Vec<String> {
        text.lines()
            .skip_while(|l| !l.starts_with("== scenario matrix"))
            .take_while(|l| !l.starts_with("ran "))
            .map(String::from)
            .collect()
    };
    let (m, s) = (table(&merged_text), table(&single_text));
    assert!(!m.is_empty(), "{merged_text}");
    assert_eq!(m, s, "merged:\n{merged_text}\nsingle:\n{single_text}");

    // Resume: re-running a shard skips all of its journaled rows.
    let again = grid(&["--shard", "0/2", "--journal", journal.to_str().unwrap()]);
    assert!(again.status.success(), "{}", String::from_utf8_lossy(&again.stderr));
    let text = String::from_utf8_lossy(&again.stdout);
    assert!(text.contains("skipped 2 already-converged rows"), "{text}");
    // ... and the resumed table still shows the journaled rows.
    assert!(text.contains("scenario matrix — 2 scenarios"), "{text}");
    assert!(text.contains("ran 0 scenarios"), "{text}");
}

#[test]
fn cli_matrix_rejects_bad_generator_axis_and_shard_values() {
    let out = bin().args(["matrix", "France", "--class-mix", "0.5,0.5"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--class-mix"));

    let out = bin().args(["matrix", "France", "--class-mix", "0.5,0.4,0.4"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("sum to 1"));

    let out = bin().args(["matrix", "France", "--noise", "abc"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--noise"));

    let out = bin().args(["matrix", "France", "--lead-min", "1.5,x"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--lead-min"));

    let out = bin().args(["matrix", "France", "--shard", "3/2"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--shard"));
}

#[test]
fn cli_matrix_rejects_bad_algo_and_opponent() {
    let out = bin().args(["matrix", "France", "--algos", "magic-9000"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown algorithm"));

    let out = bin().args(["matrix", "Atlantis", "--fast"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown opponent"));
}

#[test]
fn cli_lint_flags_violations_and_exits_nonzero() {
    let fixture = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/rust/tests/lint_fixtures/det001_violation.rs"
    );
    let out = bin().args(["lint", fixture]).output().unwrap();
    assert!(!out.status.success(), "violating fixture must fail the lint");
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("DET-001"), "{text}");
    assert!(text.contains("invariant:"), "{text}");
}

#[test]
fn cli_lint_clean_file_exits_zero_and_json_parses() {
    let fixture =
        concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/lint_fixtures/det001_ok.rs");
    let out = bin().args(["lint", "--format", "json", fixture]).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stdout));
    let report =
        sla_autoscale::analysis::parse_json(&String::from_utf8_lossy(&out.stdout)).unwrap();
    assert!(report.is_clean());
    assert_eq!(report.files_scanned, 1);
}

#[test]
fn cli_lint_rejects_unknown_format() {
    let out = bin().args(["lint", "--format", "yaml"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("format"));
}

// ---------- failure injection ----------

#[test]
fn corrupted_hlo_artifact_fails_compilation_not_process() {
    let dir = TempDir::new().unwrap();
    std::fs::write(dir.join("bad.hlo.txt"), "HloModule this is not valid hlo {{{").unwrap();
    #[cfg(not(feature = "pjrt"))]
    {
        // built without the `pjrt` feature: loading must error, not panic
        assert!(cpu_client().is_err(), "stub client must report the missing feature");
        let err =
            Executable::load(&sla_autoscale::runtime::Client, &dir.join("bad.hlo.txt"), 8, 1024, 3);
        assert!(err.is_err(), "stub loader must report the missing feature");
    }
    #[cfg(feature = "pjrt")]
    {
        let client = cpu_client().unwrap();
        let err = Executable::load(&client, &dir.join("bad.hlo.txt"), 8, 1024, 3);
        assert!(err.is_err(), "corrupted HLO must be rejected");
    }
}

#[test]
fn truncated_meta_rejected_with_context() {
    let dir = TempDir::new().unwrap();
    std::fs::write(dir.join("meta.txt"), "vocab=1024\nembed=64\n").unwrap();
    let err = Meta::load(dir.path()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("meta key missing"), "{msg}");
}

#[test]
fn zero_tweet_workload_is_a_noop_simulation() {
    let trace = Trace::default();
    let cfg = SimConfig::default();
    let model = DelayModel::default();
    let res = Simulator::new(&cfg, &model)
        .run(&trace, Box::new(ThresholdScaler::new(0.8)));
    assert_eq!(res.history.completed(), 0);
    assert_eq!(res.violation_pct(), 0.0);
}

#[test]
fn pathological_config_still_terminates() {
    // 10 ms steps, instant provisioning, sub-second adapt cadence.
    let spec = MatchSpec {
        opponent: "Edge",
        date: "—",
        total_tweets: 2_000,
        length_hours: 0.05,
        events: vec![],
    };
    let trace = generate(&spec, &GeneratorConfig::default());
    let cfg = SimConfig {
        step_secs: 0.01,
        adapt_secs: 0.5,
        provision_secs: 0.0,
        sla_secs: 10.0,
        ..Default::default()
    };
    let model = DelayModel::default();
    let res = Simulator::new(&cfg, &model)
        .run(&trace, Box::new(LoadScaler::new(model.clone(), 0.99, [0.3, 0.3, 0.4])));
    assert_eq!(res.history.completed(), trace.len() as u64);
}

#[test]
fn enormous_provisioning_delay_bounds_cost_but_hurts_quality() {
    let spec = MatchSpec {
        opponent: "SlowCloud",
        date: "—",
        total_tweets: 60_000,
        length_hours: 0.25,
        events: vec![],
    };
    let trace = generate(&spec, &GeneratorConfig::default());
    let model = DelayModel::default();
    let fast_cloud = SimConfig { provision_secs: 10.0, ..Default::default() };
    let slow_cloud = SimConfig { provision_secs: 1200.0, ..Default::default() };
    let run = |cfg: &SimConfig| {
        Simulator::new(cfg, &model)
            .run(&trace, Box::new(LoadScaler::new(model.clone(), 0.99999, [0.3, 0.3, 0.4])))
    };
    let fast = run(&fast_cloud);
    let slow = run(&slow_cloud);
    assert!(
        slow.history.mean_delay() > fast.history.mean_delay(),
        "slow provisioning must hurt delay: {:.1} vs {:.1}",
        slow.history.mean_delay(),
        fast.history.mean_delay()
    );
}

#[test]
fn scaler_names_stable_for_reports() {
    // Experiment reports key off these exact names.
    let model = DelayModel::default();
    assert_eq!(ThresholdScaler::new(0.6).name(), "threshold-60%");
    assert_eq!(
        LoadScaler::new(model.clone(), 0.9999, [0.3, 0.3, 0.4]).name(),
        "load-q99.99%"
    );
    assert_eq!(
        LoadScaler::new(model, 0.9, [0.3, 0.3, 0.4]).name(),
        "load-q90%"
    );
}
