//! Cross-process disk-cache integration tests. `clear_trace_cache()`
//! simulates a process restart: the in-memory `Arc<Trace>` cache dies
//! with the "process", the versioned on-disk store survives, and the
//! next run must read every trace back bit-identically (or regenerate
//! cleanly when a store file is damaged).
//!
//! These tests deliberately live in their own integration binary: they
//! clear the process-wide cache, which would race the `Arc::ptr_eq`
//! assertions of the unit tests. Nothing here asserts pointer identity —
//! only content bits.

use sla_autoscale::autoscale::ScalerSpec;
use sla_autoscale::config::SimConfig;
use sla_autoscale::scenario::{clear_trace_cache, Overrides, ScenarioMatrix, TraceSource};
use sla_autoscale::util::TempDir;
use sla_autoscale::workload::{store, GeneratorConfig, MatchSpec, Trace};

fn spec(opponent: &'static str, total: u64) -> MatchSpec {
    MatchSpec { opponent, date: "—", total_tweets: total, length_hours: 0.1, events: vec![] }
}

/// Every column as exact bit patterns.
fn trace_bits(t: &Trace) -> (Vec<u64>, Vec<u64>, Vec<u8>, Vec<u32>) {
    (
        t.ids().to_vec(),
        t.post_times().iter().map(|p| p.to_bits()).collect(),
        t.classes().iter().map(|&c| c as u8).collect(),
        t.sentiments().iter().map(|s| s.to_bits()).collect(),
    )
}

#[test]
fn restarted_process_reads_traces_from_disk_bit_identically() {
    let dir = TempDir::new().unwrap();
    let gens = [
        GeneratorConfig::default(),
        GeneratorConfig { lead_min: 0.0, ..GeneratorConfig::default() },
    ];
    let sources: Vec<TraceSource> = gens
        .iter()
        .map(|g| TraceSource::spec(spec("DiskIT", 10_000), false).with_generator(g.clone()))
        .collect();

    let first: Vec<_> = sources
        .iter()
        .map(|s| trace_bits(&s.load_cached(Some(dir.path())).unwrap()))
        .collect();
    assert_ne!(first[0], first[1], "generator axis must produce distinct traces");
    for s in &sources {
        assert!(s.cache_file(dir.path()).unwrap().exists(), "trace must be persisted");
    }

    // "Restart": the second process finds both traces on disk, bit-equal.
    clear_trace_cache();
    for (s, want) in sources.iter().zip(&first) {
        let again = trace_bits(&s.load_cached(Some(dir.path())).unwrap());
        assert_eq!(&again, want, "disk round trip must be bit-identical");
    }

    // Prove those reads really came from the store: restart once more and
    // plant a *different* valid trace under the first source's key — the
    // load must return the planted content, not a regeneration.
    clear_trace_cache();
    let planted = TraceSource::spec(spec("DiskITPlant", 2_000), false).load().unwrap();
    store::write_trace(&sources[0].cache_file(dir.path()).unwrap(), &planted).unwrap();
    let got = sources[0].load_cached(Some(dir.path())).unwrap();
    assert_eq!(got.len(), planted.len(), "disk store must win over regeneration");
}

#[test]
fn matrix_cache_dir_populates_the_store_and_survives_truncation() {
    let dir = TempDir::new().unwrap();
    let gens = [
        GeneratorConfig::default(),
        GeneratorConfig { lead_min: 0.0, ..GeneratorConfig::default() },
    ];
    let matrix = ScenarioMatrix::cross_gen(
        &[TraceSource::spec(spec("DiskMx", 8_000), false)],
        &gens,
        &SimConfig::default(),
        &[Overrides::default()],
        &[ScalerSpec::threshold(70.0)],
        3,
    )
    .with_cache_dir(dir.path());

    let first = matrix.run(2).unwrap();
    let files: Vec<_> = matrix
        .scenarios
        .iter()
        .map(|s| s.source.cache_file(dir.path()).unwrap())
        .collect();
    assert_ne!(files[0], files[1], "each shape gets its own store file");
    for f in &files {
        assert!(f.exists(), "matrix run must populate the store");
    }

    // "Restart" with one store file truncated: the damaged entry falls
    // back to regeneration, the intact one loads from disk, and the
    // results match the first run bit-for-bit either way.
    clear_trace_cache();
    let data = std::fs::read(&files[0]).unwrap();
    std::fs::write(&files[0], &data[..data.len() / 3]).unwrap();
    let second = matrix.run(2).unwrap();
    assert_eq!(first.len(), second.len());
    for (a, b) in first.iter().zip(&second) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.violation_pct.to_bits(), b.violation_pct.to_bits(), "{}", a.name);
        assert_eq!(a.cpu_hours.to_bits(), b.cpu_hours.to_bits(), "{}", a.name);
        assert_eq!(a.reps, b.reps, "{}", a.name);
    }
    // ... and the truncated file was healed for the next process.
    assert!(store::read_trace(&files[0]).is_ok(), "regeneration must rewrite the store");
}
