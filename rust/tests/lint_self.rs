//! Meta-test: the shipped tree must pass its own determinism lint.
//!
//! This is the static counterpart of `shard_journal`/`fleet_steal`: those
//! prove bit-identity at runtime for the interleavings they happen to
//! produce, this proves nobody has introduced a construct that could
//! break it on an interleaving they didn't. Runs the real engine over
//! `rust/src` — any unsuppressed finding fails the build, and every
//! suppression must carry its reviewable reason.

use sla_autoscale::analysis::lint_paths;
use std::path::PathBuf;

fn src_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/src")
}

#[test]
fn shipped_tree_has_no_unsuppressed_findings() {
    let report = lint_paths(&[src_root()]).unwrap();
    assert!(report.files_scanned > 20, "walked the real tree, not a stub");
    let listing: Vec<String> = report
        .findings
        .iter()
        .map(|f| format!("{}:{}: {} {}", f.file, f.line, f.rule, f.message))
        .collect();
    assert!(
        report.is_clean(),
        "determinism lint found violations in rust/src — fix them or add a \
         det:allow pragma with a reviewable reason:\n{}",
        listing.join("\n")
    );
}

#[test]
fn every_suppression_in_the_tree_is_justified() {
    let report = lint_paths(&[src_root()]).unwrap();
    assert!(!report.allowed.is_empty(), "the serve/CLI wall-clock pragmas should surface here");
    for a in &report.allowed {
        assert!(
            !a.reason.trim().is_empty(),
            "{}:{} suppresses {} without a reason",
            a.file,
            a.line,
            a.rule
        );
        assert!(a.rule.starts_with("DET-0"), "{}:{} names unknown rule {}", a.file, a.line, a.rule);
    }
}
